"""PageRank: exact power method vs numpy oracle; summarized vs exact."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import graph as G
from repro.graph.generators import barabasi_albert_edges, gnm_edges
from repro.core.pagerank import (build_summary, compact_indices, pagerank,
                                 summarized_pagerank)
from repro.core.hotset import select_hot_set


def _np_pagerank(src, dst, n, beta=0.85, iters=30):
    """Host oracle for the paper's Gelly-style formulation."""
    out_deg = np.zeros(n, np.int64)
    np.add.at(out_deg, src, 1)
    in_deg = np.zeros(n, np.int64)
    np.add.at(in_deg, dst, 1)
    active = (out_deg + in_deg) > 0
    r = np.where(active, 1.0, 0.0)
    for _ in range(iters):
        contrib = np.where(out_deg[src] > 0, r[src] / np.maximum(out_deg[src], 1), 0.0)
        acc = np.zeros(n)
        np.add.at(acc, dst, contrib)
        r = np.where(active, 0.15 + beta * acc, 0.0)
    return r, active


@pytest.mark.parametrize("seed", [0, 1])
def test_pagerank_matches_numpy_oracle(seed):
    src, dst = barabasi_albert_edges(300, 3, seed=seed)
    g = G.from_edges(src, dst, 320, 4096)
    r, it = pagerank(g, num_iters=30)
    ref, active = _np_pagerank(src, dst, 320)
    assert int(it) == 30
    np.testing.assert_allclose(np.asarray(r), ref, rtol=2e-3, atol=2e-4)


def test_pagerank_tol_early_exit():
    src, dst = gnm_edges(100, 400, seed=0)
    g = G.from_edges(src, dst, 128, 512)
    _, it_loose = pagerank(g, num_iters=100, tol=1e-1)
    _, it_tight = pagerank(g, num_iters=100, tol=0.0)
    # tol=0 may still exit once the f32 iterate reaches an exact fixpoint
    assert int(it_loose) < int(it_tight) <= 100


def test_pagerank_inactive_nodes_zero():
    src = np.array([0, 1], np.int32)
    dst = np.array([1, 0], np.int32)
    g = G.from_edges(src, dst, 10, 16)
    r, _ = pagerank(g, num_iters=10)
    assert np.all(np.asarray(r)[2:] == 0.0)


def test_pagerank_teleport_by_n_mass_conserves():
    """With /N teleport + dangling redistribution, ranks sum to ~1."""
    src, dst = barabasi_albert_edges(200, 3, seed=2)
    g = G.from_edges(src, dst, 210, 2048)
    r, _ = pagerank(g, num_iters=60, teleport_by_n=True, dangling=True)
    assert abs(float(np.asarray(r).sum()) - 1.0) < 1e-3


# ---------------------------------------------------------------------------
# summarized PageRank
# ---------------------------------------------------------------------------


def test_summarized_equals_exact_when_all_hot():
    """Oracle: K = all active vertices => summary iteration == full iteration."""
    src, dst = barabasi_albert_edges(200, 3, seed=3)
    g = G.from_edges(src, dst, 210, 2048)
    r0, _ = pagerank(g, num_iters=5)
    hot = jnp.asarray(np.asarray(g.node_active))
    summary = build_summary(g, r0, hot, hot_node_capacity=256,
                            hot_edge_capacity=2048)
    assert not bool(summary.overflow)
    assert int(summary.num_eb) == 0
    r_sum, _ = summarized_pagerank(summary, r0, num_iters=25)
    r_exact, _ = pagerank(g, r0, num_iters=25)
    np.testing.assert_allclose(np.asarray(r_sum), np.asarray(r_exact),
                               rtol=1e-5, atol=1e-6)


def test_summarized_cold_ranks_frozen():
    src, dst = barabasi_albert_edges(200, 3, seed=4)
    g = G.from_edges(src, dst, 210, 2048)
    r0, _ = pagerank(g, num_iters=10)
    deg_prev = jnp.copy(g.out_deg)
    # tag a handful of vertices hot by hand
    hot = np.zeros(210, bool)
    hot[:20] = np.asarray(g.node_active)[:20]
    summary = build_summary(g, r0, jnp.asarray(hot), hot_node_capacity=64,
                            hot_edge_capacity=1024)
    r1, _ = summarized_pagerank(summary, r0, num_iters=10)
    cold = ~hot & np.asarray(g.node_active)
    np.testing.assert_array_equal(np.asarray(r1)[cold], np.asarray(r0)[cold])


def test_b_in_matches_bruteforce():
    """Conservation: b_in equals the brute-force sum over E_B per target."""
    rng = np.random.default_rng(5)
    src, dst = gnm_edges(60, 400, seed=5)
    g = G.from_edges(src, dst, 64, 512)
    r0, _ = pagerank(g, num_iters=10)
    hot = np.zeros(64, bool)
    hot[rng.choice(60, 20, replace=False)] = True
    hot &= np.asarray(g.node_active)
    summary = build_summary(g, r0, jnp.asarray(hot), hot_node_capacity=32,
                            hot_edge_capacity=512)
    out_deg = np.asarray(g.out_deg)
    r = np.asarray(r0)
    hot_ids = np.asarray(summary.hot_ids)[: int(summary.num_hot)]
    for i, z in enumerate(hot_ids):
        ref = sum(
            r[u] / out_deg[u]
            for u, v in zip(src, dst)
            if v == z and not hot[u] and out_deg[u] > 0
        )
        np.testing.assert_allclose(float(np.asarray(summary.b_in)[i]), ref,
                                   rtol=1e-5, atol=1e-6)


def test_summary_overflow_flag():
    src, dst = gnm_edges(60, 400, seed=6)
    g = G.from_edges(src, dst, 64, 512)
    r0, _ = pagerank(g, num_iters=5)
    hot = jnp.asarray(np.asarray(g.node_active))
    summary = build_summary(g, r0, hot, hot_node_capacity=8,
                            hot_edge_capacity=512)
    assert bool(summary.overflow)


def test_summary_edge_counts_match_bruteforce():
    rng = np.random.default_rng(7)
    src, dst = gnm_edges(60, 300, seed=7)
    g = G.from_edges(src, dst, 64, 512)
    r0, _ = pagerank(g, num_iters=5)
    hot = np.zeros(64, bool)
    hot[rng.choice(60, 25, replace=False)] = True
    hot &= np.asarray(g.node_active)
    s = build_summary(g, r0, jnp.asarray(hot), hot_node_capacity=64,
                      hot_edge_capacity=512)
    ek_ref = sum(1 for u, v in zip(src, dst) if hot[u] and hot[v])
    eb_ref = sum(1 for u, v in zip(src, dst) if (not hot[u]) and hot[v])
    assert int(s.num_ek) == ek_ref
    assert int(s.num_eb) == eb_ref


# ---------------------------------------------------------------------------
# compaction helper
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    e=st.integers(1, 3000),
    density=st.floats(0.0, 1.0),
    size=st.sampled_from([16, 128, 1024]),
    seed=st.integers(0, 2**16),
)
def test_compact_indices_property(e, density, size, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(e) < density
    idx = np.asarray(compact_indices(jnp.asarray(mask), size))
    n_set = int(mask.sum())
    got = idx[idx < e]
    expect = np.nonzero(mask)[0]
    if n_set <= size:
        # exact set recovery, count filled = n_set, rest sentinel
        assert sorted(got.tolist()) == expect.tolist()
        assert (idx >= e).sum() == size - n_set
    else:
        # overflow: buffer holds `size` distinct true indices
        assert len(got) == size
        assert len(set(got.tolist())) == size
        assert set(got.tolist()) <= set(expect.tolist())

"""Differential async-correctness harness + epoch-invariant property suite.

The tentpole contract of the async rebuild pipeline
(``EngineConfig.async_rebuild=True``): every answer the async engine
serves must equal what a **synchronous oracle engine** computes when fed
the *identical* update/query interleaving, aligned at the served epoch —
updates the async engine integrated at query q become visible at query
q+1's promotion, so the oracle receives epoch e's update batches
immediately before its first query that serves epoch e.  Identical jitted
programs on identical inputs make the match **bitwise** for the
reassociation-exact min/max-semiring workloads (CC, SSSP, widest path)
and for the meshless sum algebras; allclose covers the one case where FP
reduction order can legitimately differ (mesh sum algebras across the
one-epoch-deferred rebalance recut — see ``rebalance_decision``).

Interleavings are hypothesis-driven: each example draws one integer seed
and derives a random script of add / remove / query(APPROXIMATE | EXACT |
REPEAT_LAST) events from ``np.random.default_rng(seed)`` (the shim in
``tests/_hypothesis_compat.py`` only supports scalar strategies, and a
seed keeps shapes bounded so the suite compiles a handful of programs,
not one per example).  With the real hypothesis installed the matrix is
7 algorithm cases × 30 examples ≥ 200 interleavings; the deterministic
shim runs a 5-example slice of the same space.

The satellite property suite pins the four epoch invariants:
(a) epoch ids are monotone and ``snapshot_lag`` ∈ {0, 1};
(b) no query reads a half-built summary — a served snapshot's buffers
    and layouts are immutable while later epochs build past it;
(c) promotion never skips or overwrites a completed build;
(d) drift accumulated in epoch N is charged to epoch N's stats row,
    never to N+1.
"""

import json
from pathlib import Path

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import serve_session, session
from repro.core.algorithm import Action
from repro.core.epoch import (AsyncRebuildPipeline, EpochSnapshot,
                              snapshot_counts)
from repro.graph import graph as G

# capacities are fixed across every example so the whole suite compiles a
# bounded program set (chunk shapes: update_pad-sized adds + one 4-wide
# remainder + 4-wide removal batches)
N_CAP, E_CAP = 48, 768
H_NODE, H_EDGE = 40, 512
INIT_EDGES = 90
UPDATE_PAD = 8
QUERIES = 8

MIN_SEMIRINGS = ("min_plus", "min_min", "max_times")

#: the differential matrix: every fused workload family, plus a
#: tight-capacity case that forces the overflow→exact fallback and a
#: closed-loop case where the controller's refresh decisions must also
#: replay identically.
CASES = {
    "pagerank": dict(algo="pagerank", kw={}),
    "ppr": dict(algo="personalized-pagerank", kw={"seeds": (2, 5)}),
    "sssp": dict(algo="sssp", kw={"sources": (0, 3)}),
    "cc": dict(algo="connected-components", kw={}),
    "widest": dict(algo="widest-path", kw={"sources": (1,)}),
    "pagerank-overflow": dict(algo="pagerank", kw={}, hot=(6, 12)),
    "sssp-quality": dict(algo="sssp", kw={"sources": (0,)}, quality=0.9),
}


def _make_sessions(case, src, dst, *, mesh=None, rebalance=None):
    """One async engine + one synchronous oracle, identically configured."""
    hot_n, hot_e = case.get("hot", (H_NODE, H_EDGE))
    common = dict(
        node_capacity=N_CAP, edge_capacity=E_CAP,
        hot_node_capacity=hot_n, hot_edge_capacity=hot_e,
        update_pad=UPDATE_PAD,
    )
    if case.get("quality") is not None:
        common["quality_target"] = case["quality"]
    if mesh is not None:
        common["mesh"] = mesh
        common["rebalance_threshold"] = rebalance
    mk = lambda ar: session((src, dst), case["algo"], async_rebuild=ar,
                            **common, **case["kw"])
    return mk(True), mk(False)


def _draw_script(rng, live_edges):
    """One random interleaving: per query, an optional add batch, an
    optional remove batch (always riding an add batch, so every mutating
    batch resolves and dispatches an epoch), and the OnQuery action.
    ``live_edges`` is the mutable host-side model of removable edges."""
    script = []
    for _ in range(QUERIES):
        adds, removes = [], []
        if rng.random() < 0.75:
            k = int(rng.choice([4, UPDATE_PAD]))
            adds.append((rng.integers(0, N_CAP, k).astype(np.int32),
                         rng.integers(0, N_CAP, k).astype(np.int32)))
            if live_edges and rng.random() < 0.5:
                take = min(4, len(live_edges))
                picks = [live_edges.pop(int(rng.integers(len(live_edges))))
                         for _ in range(take)]
                # pad to a fixed removal width of 4 with a definitely-dead
                # edge request, exercising the requested-but-unresolved
                # accounting without changing compiled shapes
                while len(picks) < 4:
                    picks.append(picks[-1])
                removes.append((
                    np.asarray([p[0] for p in picks], np.int32),
                    np.asarray([p[1] for p in picks], np.int32)))
        action = [Action.APPROXIMATE, Action.APPROXIMATE,
                  Action.APPROXIMATE, Action.EXACT,
                  Action.REPEAT_LAST][int(rng.integers(5))]
        script.append((adds, removes, action))
    return script


def _run_differential(case, seed, *, mesh=None, rebalance=None,
                      sum_algebra_bitwise=True):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, N_CAP, INIT_EDGES).astype(np.int32)
    dst = rng.integers(0, N_CAP, INIT_EDGES).astype(np.int32)
    # removable pool: unique initial edges (duplicate (s,d) pairs resolve
    # to one shared slot — removing both double-counts, so keep one)
    seen, live_edges = set(), []
    for s, d in zip(src.tolist(), dst.tolist()):
        if (s, d) not in seen:
            seen.add((s, d))
            live_edges.append((s, d))
    script = _draw_script(rng, live_edges)
    actions = [s[2] for s in script]

    sa, so = _make_sessions(case, src, dst, mesh=mesh, rebalance=rebalance)
    sa.engine._on_query = so.engine._on_query = (
        lambda qid, view: actions[qid])
    bitwise = (sa.algorithm.semiring in MIN_SEMIRINGS
               or sum_algebra_bitwise)

    # ---- async run, tracking the harness's own epoch model --------------
    latest = 0
    epoch_batches = {}  # epoch id -> the update batch it integrated
    async_rows = []
    for adds, removes, _action in script:
        batch = []
        for a, b in adds:
            sa.engine.register_add_edges(a, b)
            batch.append(("add", a, b))
        for a, b in removes:
            sa.engine.register_remove_edges(a, b)
            batch.append(("rm", a, b))
        res, row = sa.engine.query()
        served_epoch = latest  # promote happens before integrate
        if batch:
            latest += 1
            epoch_batches[latest] = batch
        assert row.epoch == served_epoch, (
            f"served epoch {row.epoch}, harness model says {served_epoch}")
        async_rows.append((res.copy(), row))

    # ---- oracle replay at the served epochs -----------------------------
    fed = 0
    for qid, (res_async, row) in enumerate(async_rows):
        while fed < row.epoch:
            fed += 1
            for kind, a, b in epoch_batches[fed]:
                if kind == "add":
                    so.engine.register_add_edges(a, b)
                else:
                    so.engine.register_remove_edges(a, b)
        res_oracle, row_oracle = so.engine.query()
        if bitwise:
            np.testing.assert_array_equal(
                res_async, res_oracle,
                err_msg=(f"query {qid} (epoch {row.epoch}, "
                         f"action {row.action}) diverged from the oracle"))
        else:
            np.testing.assert_allclose(
                res_async, res_oracle, rtol=1e-5, atol=1e-7,
                err_msg=(f"query {qid} (epoch {row.epoch}, "
                         f"action {row.action}) diverged from the oracle"))
        # overflow fallbacks and controller refreshes must replay too —
        # they change *which* program produced the answer
        assert row.overflow_fallback == row_oracle.overflow_fallback
        assert row.refreshed == row_oracle.refreshed
    return async_rows


# ---------------------------------------------------------------------------
# tentpole: the differential harness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case_name", sorted(CASES))
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_async_engine_matches_sync_oracle(case_name, seed):
    """Every served answer equals the synchronous oracle at the served
    epoch — bitwise (min semirings AND meshless sum algebras: identical
    programs, identical inputs) across random interleavings of add /
    remove / approximate / exact / repeat-last / overflow-fallback /
    controller-refresh events."""
    _run_differential(CASES[case_name], seed)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="mesh case needs >= 2 devices "
                           "(CI forces 8 host devices)")
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_async_mesh_rebalance_matches_oracle_bitwise(seed):
    """Sharded engines with live rebalancing: the async recut lands one
    epoch later than the sync engine's (the verdict is fetched at
    promotion), which only reorders ⊕ — so the min-semiring workloads
    must still match the oracle **bitwise** through recut epochs."""
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:2]), ("d",))
    _run_differential(CASES["cc"], seed, mesh=mesh, rebalance=0.75)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="mesh case needs >= 2 devices")
def test_async_mesh_sum_algebra_matches_oracle_allclose():
    """Mesh sum algebras across a deferred recut: allclose (FP reduction
    order differs at exactly the recut epoch, nowhere else)."""
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:2]), ("d",))
    _run_differential(CASES["pagerank"], seed=7, mesh=mesh, rebalance=0.5,
                      sum_algebra_bitwise=False)


# ---------------------------------------------------------------------------
# satellite: epoch-invariant property suite
# ---------------------------------------------------------------------------


def _started_async(seed=0, **over):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, N_CAP, INIT_EDGES).astype(np.int32)
    dst = rng.integers(0, N_CAP, INIT_EDGES).astype(np.int32)
    over.setdefault("node_capacity", N_CAP)
    over.setdefault("edge_capacity", E_CAP)
    over.setdefault("hot_node_capacity", H_NODE)
    over.setdefault("hot_edge_capacity", H_EDGE)
    over.setdefault("update_pad", UPDATE_PAD)
    return session((src, dst), "pagerank", async_rebuild=True, **over), rng


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_epoch_ids_monotone_and_lag_bounded(seed):
    """(a) Epoch ids never decrease, advance by at most one per query,
    and snapshot_lag is always 0 or 1 (double buffering, by
    construction)."""
    s, rng = _started_async(seed)
    prev_epoch = 0
    for q in range(10):
        if rng.random() < 0.7:
            s.engine.register_add_edges(
                rng.integers(0, N_CAP, UPDATE_PAD).astype(np.int32),
                rng.integers(0, N_CAP, UPDATE_PAD).astype(np.int32))
        _, row = s.engine.query()
        assert row.epoch >= prev_epoch
        assert row.epoch - prev_epoch <= 1
        assert row.snapshot_lag in (0, 1)
        # no buffered mutations -> the engine must not invent an epoch
        if row.pending_applied == 0 and row.epoch > 0:
            assert row.epoch == prev_epoch
        prev_epoch = row.epoch


def test_snapshot_immutable_while_next_epoch_builds():
    """(b) No query reads a half-built summary: the served snapshot's
    graph buffers and sorted layouts are unchanged — value-identical on
    host — while later epochs apply updates and build past it."""
    s, rng = _started_async(3)
    eng = s.engine
    snap = eng._pipeline.current
    layouts = eng._snapshot_layouts(snap)
    frozen = jax.device_get({
        "src": snap.state.src, "dst": snap.state.dst,
        "alive": snap.state.edge_alive, "num_edges": snap.state.num_edges,
        "out_deg": snap.state.out_deg, "deg": snap.deg,
        "lay_dst": layouts[0].dst, "lay_w": layouts[0].weight,
    })
    for _ in range(4):  # several epochs of churn past the frozen snapshot
        eng.register_add_edges(
            rng.integers(0, N_CAP, UPDATE_PAD).astype(np.int32),
            rng.integers(0, N_CAP, UPDATE_PAD).astype(np.int32))
        eng.query()
    after = jax.device_get({
        "src": snap.state.src, "dst": snap.state.dst,
        "alive": snap.state.edge_alive, "num_edges": snap.state.num_edges,
        "out_deg": snap.state.out_deg, "deg": snap.deg,
        "lay_dst": layouts[0].dst, "lay_w": layouts[0].weight,
    })
    for key in frozen:
        np.testing.assert_array_equal(
            frozen[key], after[key],
            err_msg=f"snapshot buffer {key!r} mutated under async builds")
    # and the snapshot's layout cache never rebuilds: same objects
    assert eng._snapshot_layouts(snap)[0] is layouts[0]


def test_promotion_never_skips_or_overwrites_a_build():
    """(c) Pipeline discipline: a dispatched build must be promoted
    before the next dispatch; epoch ids must be successors; after a
    drained stream promotions == dispatches (nothing lost)."""
    state = G.from_edges(np.asarray([0, 1], np.int32),
                         np.asarray([1, 2], np.int32), 8, 16)

    def snap(epoch):
        return EpochSnapshot(
            epoch=epoch, state=state,
            deg=state.out_deg, active=state.node_active,
            counts=snapshot_counts(state))

    pipe = AsyncRebuildPipeline(snap(0))
    assert pipe.promote() is None  # nothing in flight: promote is a no-op
    pipe.dispatch(snap(1))
    assert pipe.snapshot_lag == 1
    with pytest.raises(RuntimeError, match="never +promoted"):
        pipe.dispatch(snap(2))  # would overwrite (= skip) epoch 1
    promoted = pipe.promote()
    assert promoted is not None and promoted.epoch == 1
    assert pipe.current is promoted and pipe.snapshot_lag == 0
    with pytest.raises(RuntimeError, match="non-monotone"):
        pipe.dispatch(snap(3))  # 1 -> 3 skips epoch 2
    pipe.dispatch(snap(2))
    pipe.promote()
    assert pipe.promotions == pipe.dispatches == 2

    # the engine end-to-end: epochs promoted == epochs dispatched once
    # the stream drains (every build became a served epoch)
    s, rng = _started_async(11)
    for _ in range(6):
        s.engine.register_add_edges(
            rng.integers(0, N_CAP, 4).astype(np.int32),
            rng.integers(0, N_CAP, 4).astype(np.int32))
        s.engine.query()
    s.engine.query()  # boundary with nothing pending: promotes the last build
    epipe = s.engine._pipeline
    assert epipe.building is None
    assert epipe.promotions == epipe.dispatches == epipe.current.epoch


def test_drift_charged_to_the_epoch_that_accumulated_it():
    """(d) A huge buffered burst must not leak into the quiet epoch
    being served: the query that *dispatches* the burst still reports
    epoch N with (near-)zero drift, and the burst's churn lands on the
    next row, stamped epoch N+1."""
    s, rng = _started_async(5, quality_target=0.9)
    eng = s.engine
    quiet_rows = [eng.query()[1] for _ in range(3)]  # settle, no updates
    quiet = quiet_rows[-1]
    assert quiet.epoch == 0 and quiet.pending_applied == 0

    burst = 4 * UPDATE_PAD  # several chunks of fresh churn
    eng.register_add_edges(
        rng.integers(0, N_CAP, burst).astype(np.int32),
        rng.integers(0, N_CAP, burst).astype(np.int32))
    _, dispatch_row = eng.query()  # serves quiet epoch 0, dispatches 1
    _, visible_row = eng.query()   # serves epoch 1: the burst is visible

    assert dispatch_row.epoch == quiet.epoch
    assert visible_row.epoch == quiet.epoch + 1
    assert visible_row.pending_applied == burst
    # the quiet epoch's row reads like the quiet baseline: no burst
    # drift, no controller reaction
    assert dispatch_row.drift == pytest.approx(quiet.drift, abs=1e-6)
    assert not dispatch_row.refreshed
    # ...and the churn is charged to the epoch that integrated it — the
    # controller reacts on N+1's row (an SLO-breach refresh if the burst
    # blew the budget, a raw drift reading otherwise)
    assert visible_row.refreshed or visible_row.drift > dispatch_row.drift


def test_unresolved_removals_report_on_the_current_row():
    """A removal batch that matches no live edge mutates nothing: no new
    epoch is dispatched, and the request surfaces on the row that
    processed it instead of vanishing."""
    s, _ = _started_async(9)
    s.engine.register_remove_edges(
        np.asarray([N_CAP - 1] * 4, np.int32),
        np.asarray([N_CAP - 1] * 4, np.int32))
    _, row = s.engine.query()
    assert row.epoch == 0 and row.removals_requested == 4
    assert s.engine._pipeline.building is None
    _, row2 = s.engine.query()
    assert row2.epoch == 0  # still nothing to promote


def test_async_requires_fused_path():
    with pytest.raises(ValueError, match="async_rebuild requires"):
        _started_async(0, fused=False)


# ---------------------------------------------------------------------------
# serving: the wave loop on the same pipeline
# ---------------------------------------------------------------------------


def test_serving_waves_promote_at_boundaries_and_match_semantics():
    """The serving engine serves whole waves from one snapshot: updates
    buffered mid-wave become visible exactly one wave later, and the
    ServeStats epoch/lag columns track the pipeline."""
    rng = np.random.default_rng(0)
    src = rng.integers(0, N_CAP, INIT_EDGES).astype(np.int32)
    dst = rng.integers(0, N_CAP, INIT_EDGES).astype(np.int32)
    srv = serve_session((src, dst), slots=2,
                        node_capacity=N_CAP, edge_capacity=E_CAP,
                        hot_node_capacity=H_NODE, hot_edge_capacity=H_EDGE,
                        update_pad=UPDATE_PAD, async_rebuild=True)
    t0 = srv.submit("personalized-pagerank", seeds=(3,))
    srv.step()
    assert t0.done and srv.stats.epoch == 0
    srv.add_edges(rng.integers(0, N_CAP, UPDATE_PAD).astype(np.int32),
                  rng.integers(0, N_CAP, UPDATE_PAD).astype(np.int32))
    t1 = srv.submit("personalized-pagerank", seeds=(3,))
    srv.step()  # dispatched the build, but this wave still served epoch 0
    assert t1.done and srv.stats.epoch == 0 and srv.stats.snapshot_lag == 1
    np.testing.assert_array_equal(t0.result, t1.result)
    t2 = srv.submit("personalized-pagerank", seeds=(3,))
    srv.step()  # the promotion boundary: updates visible now
    assert t2.done and srv.stats.epoch == 1 and srv.stats.snapshot_lag == 0
    assert not np.array_equal(t1.result, t2.result)

    # differential: a sync serving engine fed the same updates *before*
    # the wave that serves them answers identically at that epoch
    srv_sync = serve_session((src, dst), slots=2,
                             node_capacity=N_CAP, edge_capacity=E_CAP,
                             hot_node_capacity=H_NODE,
                             hot_edge_capacity=H_EDGE,
                             update_pad=UPDATE_PAD, async_rebuild=False)
    u0 = srv_sync.submit("personalized-pagerank", seeds=(3,))
    srv_sync.step()
    np.testing.assert_array_equal(t0.result, u0.result)


def test_bench_sweeps_records_async_overlap_acceptance():
    """BENCH_sweeps.json carries the ISSUE 10 acceptance numbers: query
    p95 during a write burst is better on the async engine than the sync
    one (the deferred rebuild drains into inter-query think-time)."""
    root = Path(__file__).resolve().parent.parent
    record = json.loads((root / "BENCH_sweeps.json").read_text())
    overlap = record["meta"]["async_overlap"]
    assert overlap["async_p95_us"] < overlap["sync_p95_us"]
    assert overlap["p95_speedup"] > 1.0
    names = {row["name"] for row in record["rows"]}
    assert {"async_overlap_sync_query_p50",
            "async_overlap_sync_query_p95",
            "async_overlap_async_query_p50",
            "async_overlap_async_query_p95"} <= names

"""Stream building: conservation, chunking, shuffle determinism."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.graph.generators import gnm_edges
from repro.stream import StreamConfig, build_stream


def test_stream_conserves_edges():
    src, dst = gnm_edges(100, 2000, seed=0)
    cfg = StreamConfig(stream_size=500, num_queries=10, shuffle=True, seed=3)
    es = build_stream(src, dst, cfg)
    total = es.init_src.shape[0] + sum(s.shape[0] for s, _ in es.chunks)
    assert total == src.shape[0] - (500 % 10)  # only whole chunks are kept
    # every stream edge is from the dataset
    ds = {(int(a), int(b)) for a, b in zip(src, dst)}
    for s, d in es.chunks:
        for a, b in zip(s, d):
            assert (int(a), int(b)) in ds


def test_stream_deterministic_given_seed():
    src, dst = gnm_edges(50, 400, seed=1)
    cfg = StreamConfig(stream_size=100, num_queries=5, shuffle=True, seed=9)
    e1 = build_stream(src, dst, cfg)
    e2 = build_stream(src, dst, cfg)
    np.testing.assert_array_equal(e1.init_src, e2.init_src)
    for (a1, b1), (a2, b2) in zip(e1.chunks, e2.chunks):
        np.testing.assert_array_equal(a1, a2)
        np.testing.assert_array_equal(b1, b2)


def test_unshuffled_preserves_dataset_order():
    src, dst = gnm_edges(50, 400, seed=2)
    # dedupe: duplicate edges make dataset positions ambiguous
    key = src.astype(np.int64) * 2**32 + dst.astype(np.int64)
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    src, dst = src[idx], dst[idx]
    cfg = StreamConfig(stream_size=100, num_queries=5, shuffle=False, seed=9)
    es = build_stream(src, dst, cfg)
    flat_s = np.concatenate([s for s, _ in es.chunks])
    # order of sampled edges matches their relative order in the dataset
    ds = {(int(a), int(b)): i for i, (a, b) in enumerate(zip(src, dst))}
    flat_d = np.concatenate([d for _, d in es.chunks])
    positions = [ds[(int(a), int(b))] for a, b in zip(flat_s, flat_d)]
    assert positions == sorted(positions)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(50, 500),
    q=st.integers(1, 20),
    ssize=st.integers(10, 200),
    seed=st.integers(0, 2**16),
)
def test_chunks_uniform_size(m, q, ssize, seed):
    src, dst = gnm_edges(40, m, seed=seed % 7)
    m = src.shape[0]
    cfg = StreamConfig(stream_size=ssize, num_queries=q, shuffle=True, seed=seed)
    es = build_stream(src, dst, cfg)
    assert len(es.chunks) == q
    sizes = {s.shape[0] for s, _ in es.chunks}
    assert len(sizes) == 1  # all chunks equal size
    assert sizes.pop() == min(ssize, m // 2) // q

"""Multi-device parity suite for the sharded propagation backend.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
sharded job does) these tests drive the real ``shard_map`` path over an
8-device mesh; on a single device the same tests still run — the mesh
shrinks to the available devices and the mesh-less shard-loop reference
path keeps 8-way partitioning covered regardless.

The contract:

- sharded ``push`` == single-layout ``push`` for every registered semiring
  × weight mode — **bitwise** for the min-reduce semirings (min/pmin is
  reassociation-exact), to f32 summation order for sum/max-of-products;
- sharded ``build_summary`` (the distributed bucket sort) == the
  replicated construction: same counters, identical E_K edge multiset,
  same ``b_in`` boundary and the same summarized-sweep answers (bitwise
  for min semirings);
- sharded ``fused_query_step`` == the unsharded engine answer for every
  registered algorithm (bitwise for the min-semiring workloads at full
  hot-set coverage);
- a mesh-configured engine under a forced-imbalance stream *rebalances*
  (recuts its slot partition) and keeps answering identically to the
  single-device engine;
- the sharded plugin path traces **zero** unsorted ``push_coo`` calls.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

import repro
from repro.core import backend as B
from repro.core.algorithm import available_algorithms, make_algorithm
from repro.core.fused import fused_query_step
from repro.core.semiring import resolve_semiring
from repro.graph import from_edges
from repro.graph.generators import gnm_edges
from repro.graph.partition import build_sharded_layout

TOL = dict(rtol=1e-5, atol=1e-6)

#: every registered semiring × a weight mode it supports
SEMIRING_WEIGHTS = [
    ("plus_times", "inv_out"),
    ("plus_times", "unit"),
    ("min_plus", "length"),
    ("min_min", "unit"),
    ("max_times", "unit"),
]
#: reduces for which sharding must be bitwise (reassociation-exact ⊕)
BITWISE_ADDS = ("min",)


def _mesh(max_devices: int = 8) -> Mesh:
    """A 1-D mesh over up to ``max_devices`` of the available devices."""
    n = min(jax.device_count(), max_devices)
    return Mesh(np.asarray(jax.devices()[:n]), ("shards",))


def _graph(n=300, m=2000, seed=0, n_cap=None):
    src, dst = gnm_edges(n, m, seed=seed)
    return from_edges(src, dst, n_cap or n, m + 64)


def _values(semiring, n, seed=0):
    s = resolve_semiring(semiring)
    rng = np.random.default_rng(seed)
    if np.issubdtype(s.np_dtype, np.floating):
        return jnp.asarray(rng.random(n).astype(s.np_dtype))
    return jnp.asarray(rng.integers(0, n, n).astype(s.np_dtype))


def _assert_matches(out, ref, semiring):
    s = resolve_semiring(semiring)
    assert out.dtype == ref.dtype
    if s.add in BITWISE_ADDS:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_suite_sees_forced_host_devices():
    """Under the sharded CI job (8 forced host devices) the mesh really
    spans 8 devices; elsewhere this documents what the run covered."""
    mesh = _mesh()
    assert mesh.devices.size == min(jax.device_count(), 8)
    if jax.device_count() >= 8:
        assert mesh.devices.size == 8


# ------------------------------------------------------------- push parity
@pytest.mark.parametrize("semiring,weight", SEMIRING_WEIGHTS)
@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_sharded_push_matches_single_device(semiring, weight, backend):
    g = _graph()
    values = _values(semiring, g.node_capacity)
    ref = B.push(values, B.build_layout(g, weight=weight, semiring=semiring),
                 semiring=semiring, backend="segment_sum")
    mesh = _mesh()
    sharded = build_sharded_layout(g, mesh=mesh, weight=weight,
                                   semiring=semiring)
    out = B.push(values, sharded, semiring=semiring, backend=backend,
                 interpret=True)
    _assert_matches(out, ref, semiring)


@pytest.mark.parametrize("semiring,weight", SEMIRING_WEIGHTS)
def test_shard_loop_path_matches_single_device(semiring, weight):
    """mesh=None: the on-device shard loop is the reference semantics and
    keeps 8-way partitioning covered even on one device."""
    g = _graph(seed=3)
    values = _values(semiring, g.node_capacity, seed=4)
    ref = B.push(values, B.build_layout(g, weight=weight, semiring=semiring),
                 semiring=semiring, backend="segment_sum")
    sharded = build_sharded_layout(g, num_shards=8, weight=weight,
                                   semiring=semiring)
    out = B.push(values, sharded, semiring=semiring, backend="segment_sum")
    _assert_matches(out, ref, semiring)


def test_sharded_push_with_explicit_lengths_and_mask():
    """Per-edge lengths bake into the shards; masks filter the sharded
    sorted stream (the b_in boundary selection shape)."""
    g = _graph(n=200, m=1200, seed=5, n_cap=200)
    lengths = jnp.asarray(
        np.random.default_rng(6).uniform(0.5, 2.0, g.edge_capacity),
        jnp.float32)
    dist = _values("min_plus", 200, seed=7)
    single = B.build_layout(g, weight="length", semiring="min_plus",
                            lengths=lengths)
    sharded = build_sharded_layout(g, mesh=_mesh(), weight="length",
                                   semiring="min_plus", lengths=lengths)
    ref = B.push(dist, single, semiring="min_plus", backend="segment_sum")
    out = B.push(dist, sharded, semiring="min_plus", backend="segment_sum")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # masked: keep only edges into even receivers, in each stream's order
    m_single = (single.dst % 2) == 0
    m_sharded = (sharded.dst % 2) == 0
    ref_m = B.push(dist, single, semiring="min_plus", mask=m_single,
                   backend="segment_sum")
    out_m = B.push(dist, sharded, semiring="min_plus", mask=m_sharded,
                   backend="segment_sum")
    np.testing.assert_array_equal(np.asarray(out_m), np.asarray(ref_m))


def test_sharded_push_trace_time_guards():
    g = _graph(n=64, m=300, seed=8, n_cap=64)
    sharded = build_sharded_layout(g, num_shards=4, weight="unit",
                                   semiring="min_min")
    with pytest.raises(ValueError, match="sharded layout built for"):
        B.push(jnp.ones(64), sharded, semiring="plus_times")
    with pytest.raises(ValueError, match="mask must cover"):
        B.push(jnp.zeros(64, jnp.int32), sharded, semiring="min_min",
               mask=jnp.ones(64, bool), backend="segment_sum")
    with pytest.raises(ValueError, match="not in mesh"):
        build_sharded_layout(g, mesh=_mesh(), axes=("bogus",))
    with pytest.raises(ValueError, match="mesh= or num_shards="):
        build_sharded_layout(g)
    if jax.device_count() >= 2:  # with 1 device every shard count divides
        with pytest.raises(ValueError, match="multiple"):
            build_sharded_layout(g, mesh=_mesh(2), num_shards=3)


# -------------------------------------------- sharded build_summary parity
def _ek_triples(summary):
    """The valid (src, dst, w) E_K triples of a summary, order-normalized —
    flat and sharded buffers store the same multiset in different shapes."""
    k_cap = summary.hot_ids.shape[0]
    src = np.asarray(summary.ek_src)
    dst = np.asarray(summary.ek_dst)
    w = np.asarray(summary.ek_w)
    if src.ndim == 1:
        valid = np.arange(src.shape[0]) < int(summary.num_ek)
    else:
        valid = dst < k_cap
    t = np.stack([src[valid].astype(np.float64),
                  dst[valid].astype(np.float64),
                  w[valid].astype(np.float64)])
    return t[:, np.lexsort(t)]


#: (algorithm summary spec) -> the build_summary kwargs it exercises
SUMMARY_SPECS = [
    ("inv_out", False, "plus_times"),   # PageRank
    ("unit", False, "plus_times"),      # HITS fwd / Katz
    ("unit", True, "plus_times"),       # HITS rev
    ("unit", False, "min_min"),         # CC fwd
    ("unit", True, "min_min"),          # CC rev
    ("length", False, "min_plus"),      # SSSP
]


@pytest.mark.parametrize("weight,reverse,semiring", SUMMARY_SPECS)
def test_sharded_build_summary_matches_replicated(weight, reverse, semiring):
    """The distributed bucket sort builds the same summary the replicated
    compaction does: identical relabelling, counters and boundary, the same
    E_K edge multiset, and identical summarized pushes (bitwise for min)."""
    from repro.core.pagerank import build_summary

    g = _graph(n=280, m=1800, seed=21)
    values = _values(semiring, g.node_capacity, seed=22)
    hot = jnp.asarray(
        np.random.default_rng(23).random(g.node_capacity) < 0.3)
    caps = dict(hot_node_capacity=128, hot_edge_capacity=1024)
    kw = dict(weight=weight, reverse=reverse, semiring=semiring)
    ref = build_summary(g, values, hot, **caps, **kw)
    sharded_layout = build_sharded_layout(g, mesh=_mesh(), **kw)
    sh = build_summary(g, values, hot, **caps, layout=sharded_layout, **kw)

    assert sh.sharded and not ref.sharded
    assert sh.num_shards == sharded_layout.num_shards
    for field in ("num_hot", "num_ek", "num_eb", "overflow"):
        assert int(getattr(sh, field)) == int(getattr(ref, field)), field
    np.testing.assert_array_equal(np.asarray(sh.hot_ids),
                                  np.asarray(ref.hot_ids))
    _assert_matches(sh.b_in, ref.b_in, semiring)
    np.testing.assert_array_equal(_ek_triples(sh), _ek_triples(ref))
    # the summarized sweep consumes both forms through summary_layout/push
    local = _values(semiring, 128, seed=24)
    out_ref = B.push(local, B.summary_layout(ref, semiring=semiring),
                     semiring=semiring, backend="segment_sum")
    out_sh = B.push(local, B.summary_layout(sh, semiring=semiring),
                    semiring=semiring, backend="segment_sum")
    _assert_matches(out_sh, out_ref, semiring)


def test_sharded_summarized_sweeps_match_replicated():
    """End-to-end over the summarized kernels: PageRank (f32 tolerance) and
    SSSP (bitwise) answers agree between the two summary forms."""
    from repro.core.pagerank import (build_summary, pagerank,
                                     summarized_pagerank)
    from repro.core.traversal import sssp, summarized_sssp

    g = _graph(n=260, m=1600, seed=25)
    hot = jnp.asarray(
        np.random.default_rng(26).random(g.node_capacity) < 0.4)
    caps = dict(hot_node_capacity=160, hot_edge_capacity=2048)
    ranks, _ = pagerank(g, num_iters=5)
    ref = build_summary(g, ranks, hot, **caps)
    sh = build_summary(
        g, ranks, hot, **caps,
        layout=build_sharded_layout(g, mesh=_mesh(), weight="inv_out"))
    r_ref, _ = summarized_pagerank(ref, ranks, num_iters=10)
    r_sh, _ = summarized_pagerank(sh, ranks, num_iters=10)
    np.testing.assert_allclose(np.asarray(r_sh), np.asarray(r_ref), **TOL)

    source = jnp.zeros((g.node_capacity,), bool).at[0].set(True)
    dist, _ = sssp(g, source, num_iters=5)
    kw = dict(weight="length", semiring="min_plus")
    ref_m = build_summary(g, dist, hot, **caps, **kw)
    sh_m = build_summary(
        g, dist, hot, **caps, **kw,
        layout=build_sharded_layout(g, mesh=_mesh(), **kw))
    d_ref, _ = summarized_sssp(ref_m, dist, source, num_iters=10)
    d_sh, _ = summarized_sssp(sh_m, dist, source, num_iters=10)
    np.testing.assert_array_equal(np.asarray(d_sh), np.asarray(d_ref))


def test_sharded_summary_bucket_overflow_flags():
    """A destination bucket past its ⌈H_cap/S⌉ capacity raises ``overflow``
    even when |E_K| fits globally (the caller falls back to exact), and a
    roomy H_cap over the same skewed graph stays clean."""
    from repro.core.pagerank import build_summary

    # a star whose edges all sit in the FIRST slot shard (huge append
    # headroom) and all land on vertex 0: one (source shard, bucket) block
    # must carry every E_K edge
    n, m = 64, 20
    src = np.arange(1, m + 1, dtype=np.int32)
    dst = np.zeros(m, np.int32)
    g = from_edges(src, dst, n, 512)  # E_s = 64 -> all lives in shard 0
    hot = jnp.ones((n,), bool)
    ranks = jnp.ones((n,), jnp.float32)
    layout = build_sharded_layout(g, num_shards=8, weight="inv_out")
    # H_cap = 64 -> per-block capacity ⌈64/8⌉ = 8 < 20 edges in one block,
    # even though |E_K| = 20 fits H_cap globally
    tight = build_summary(g, ranks, hot, hot_node_capacity=n,
                          hot_edge_capacity=64, layout=layout)
    assert int(tight.num_ek) == m <= 64
    assert bool(tight.overflow)
    # same graph, H_cap sized so the block fits -> clean flag, full E_K
    roomy = build_summary(g, ranks, hot, hot_node_capacity=n,
                          hot_edge_capacity=8 * m, layout=layout)
    assert not bool(roomy.overflow)
    assert int(roomy.num_ek) == m


# --------------------------------------------------- engine shard rebalance
@pytest.mark.parametrize("name", ["sssp", "connected-components", "pagerank"])
def test_forced_imbalance_stream_triggers_rebalance(name):
    """A stream over a front-loaded edge buffer (huge append headroom ->
    every live slot in the head shards) must trip the engine's rebalance
    threshold exactly once, recut to an even partition, and keep the
    answers equal to the single-device engine — bitwise for the
    min-semiring workloads."""
    src, dst = gnm_edges(220, 1300, seed=31)
    kw = {"sssp": dict(sources=(0,))}.get(name, {})
    common = dict(algorithm=name, num_iters=8, edge_capacity=16384, **kw)
    with repro.session((src, dst), **common) as ref, \
         repro.session((src, dst), mesh=_mesh(), num_shards=8,
                       **common) as sh:
        assert sh.engine.config.rebalance_threshold == 1.0  # on by default
        assert sh.engine.rebalances == 0  # nothing measured before a query
        for s in (ref, sh):
            s.add_edges(np.arange(50), np.arange(50) + 100)
        r_ref = ref.query()
        r_sh = sh.query()
        assert sh.engine.rebalances == 1
        assert r_sh.stats.rebalanced
        assert sh.engine.last_imbalance > 1.0
        _assert_matches(np.asarray(r_sh.scores), np.asarray(r_ref.scores),
                        sh.algorithm.semiring)
        # the recut assignment is (near-)even and further balanced appends
        # do not re-trigger (dead slots were dealt round-robin too)
        from repro.graph.partition import shard_live_counts
        counts = np.asarray(
            shard_live_counts(sh.engine.state, sh.engine._shard_slots))
        assert counts.max() - counts.min() <= 1
        for s in (ref, sh):
            s.add_edges(np.arange(60), np.arange(60) + 30)
        r2_ref = ref.query()
        r2_sh = sh.query()
        assert sh.engine.rebalances == 1
        assert not r2_sh.stats.rebalanced
        _assert_matches(np.asarray(r2_sh.scores), np.asarray(r2_ref.scores),
                        sh.algorithm.semiring)


def test_num_shards_without_mesh_rejected():
    """num_shards only feeds the mesh layout/rebalance path; accepting it
    meshless would silently run unsharded."""
    src, dst = gnm_edges(40, 150, seed=33)
    with pytest.raises(ValueError, match="num_shards requires mesh"):
        repro.session((src, dst), algorithm="pagerank", num_shards=8)


def test_rebalance_disabled_and_threshold_none():
    """rebalance_threshold=None restores the contiguous-cut behaviour (the
    pre-rebalance engine) without touching results."""
    src, dst = gnm_edges(150, 800, seed=32)
    with repro.session((src, dst), algorithm="pagerank", num_iters=6,
                       edge_capacity=8192, mesh=_mesh(), num_shards=8,
                       rebalance_threshold=None) as s:
        s.add_edges([1, 2, 3], [4, 5, 6])
        s.query()
        assert s.engine.rebalances == 0
        assert s.engine._shard_slots is None


# ------------------------------------------------- fused query step parity
def _algo(name, num_iters=8):
    params = {"personalized-pagerank": dict(seeds=(1, 5))}.get(name, {})
    a = make_algorithm(name, **params)
    return a.__class__(**{**{f: getattr(a, f) for f in a.__dataclass_fields__},
                          "num_iters": num_iters})


@pytest.mark.parametrize("name", sorted(available_algorithms()))
def test_sharded_fused_query_step_matches_unsharded(name):
    """Full hot coverage: the summarized answer equals the exact sweep, so
    sharded-vs-unsharded disagreements cannot hide behind approximation."""
    g = _graph(n=250, m=1500, seed=10)
    algo = _algo(name)
    st0 = algo.init_state(g)
    st, _ = algo.exact(st0, g, backend="segment_sum")
    deg = jnp.copy(g.out_deg)
    act = jnp.copy(g.node_active)
    caps = dict(hot_node_capacity=g.node_capacity,
                hot_edge_capacity=g.edge_capacity)
    args = (g, st, deg, act, jnp.float32(0.0), jnp.float32(0.1))
    single = tuple(
        B.build_layout(g, weight=w, reverse=rev, semiring=s)
        for (w, rev, s) in map(B.normalize_layout_spec, algo.layout_specs))
    sharded = tuple(
        build_sharded_layout(g, mesh=_mesh(), weight=w, reverse=rev,
                             semiring=s)
        for (w, rev, s) in map(B.normalize_layout_spec, algo.layout_specs))
    ref_state, ref_stats = fused_query_step(
        *args, algo=algo, **caps, layouts=single, backend="segment_sum")
    out_state, out_stats = fused_query_step(
        *args, algo=algo, **caps, layouts=sharded, backend="segment_sum")
    assert not bool(ref_stats.used_fallback)
    assert int(out_stats.num_hot) == int(ref_stats.num_hot)
    assert int(out_stats.num_ek) == int(ref_stats.num_ek)
    for k in ref_state:
        _assert_matches(out_state[k], ref_state[k], algo.semiring)


@pytest.mark.parametrize("name", sorted(available_algorithms()))
def test_session_mesh_matches_unsharded_engine(name):
    """End to end through ``session(..., mesh=...)``: ingest a chunk, query,
    compare against the mesh-less engine."""
    src, dst = gnm_edges(220, 1300, seed=11)
    kw = {"sssp": dict(sources=(0,)),
          "personalized-pagerank": dict(seeds=(2,))}.get(name, {})
    with repro.session((src, dst), algorithm=name, num_iters=8, **kw) as ref, \
         repro.session((src, dst), algorithm=name, num_iters=8,
                       mesh=_mesh(), **kw) as sh:
        for s in (ref, sh):
            s.add_edges([1, 2, 3, 7], [4, 5, 6, 9])
        r_ref = ref.query()
        r_sh = sh.query()
        assert r_sh.action == r_ref.action
        _assert_matches(np.asarray(r_sh.scores), np.asarray(r_ref.scores),
                        sh.algorithm.semiring)
        # the sharded layout cache behaves like the single one, and its
        # arrays are placed across the mesh once per cache fill (not
        # re-distributed by every consuming shard_map)
        assert sh.engine.layout_builds == ref.engine.layout_builds
        lay = sh.engine.edge_layouts()[0]
        assert isinstance(lay, B.ShardedEdgeLayout)
        assert len(lay.src.sharding.device_set) == lay.mesh.devices.size


def test_sharded_plugin_path_traces_zero_push_coo():
    """The acceptance gate the dry-run enforces, pinned here: lowering the
    sharded ``fused_query_step`` touches no unsorted ``push_coo``."""
    g = _graph(n=251, m=1100, seed=12, n_cap=251)  # unique shapes => fresh trace
    algo = _algo("pagerank", num_iters=5)
    st = algo.init_state(g)
    mesh = _mesh()
    B.reset_trace_counts()
    fused_query_step(
        g, st, jnp.copy(g.out_deg), jnp.copy(g.node_active),
        jnp.float32(0.2), jnp.float32(0.1), algo=algo,
        hot_node_capacity=128, hot_edge_capacity=1024,
        backend="segment_sum", mesh=mesh)
    assert B.trace_count("push_coo") == 0
    # the mesh-less fallback (no layouts, no mesh) still goes through the
    # unsorted path — the counter is live, not vacuously zero
    B.reset_trace_counts()
    B.push_coo(jnp.ones(4), jnp.zeros(2, jnp.int32),
               jnp.ones(2, jnp.int32), 4)
    assert B.trace_count("push_coo") == 1

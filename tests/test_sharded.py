"""Multi-device parity suite for the sharded propagation backend.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
sharded job does) these tests drive the real ``shard_map`` path over an
8-device mesh; on a single device the same tests still run — the mesh
shrinks to the available devices and the mesh-less shard-loop reference
path keeps 8-way partitioning covered regardless.

The contract:

- sharded ``push`` == single-layout ``push`` for every registered semiring
  × weight mode — **bitwise** for the min-reduce semirings (min/pmin is
  reassociation-exact), to f32 summation order for sum/max-of-products;
- sharded ``fused_query_step`` == the unsharded engine answer for every
  registered algorithm (bitwise for the min-semiring workloads at full
  hot-set coverage);
- the sharded plugin path traces **zero** unsorted ``push_coo`` calls.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

import repro
from repro.core import backend as B
from repro.core.algorithm import available_algorithms, make_algorithm
from repro.core.fused import fused_query_step
from repro.core.semiring import resolve_semiring
from repro.graph import from_edges
from repro.graph.generators import gnm_edges
from repro.graph.partition import build_sharded_layout

TOL = dict(rtol=1e-5, atol=1e-6)

#: every registered semiring × a weight mode it supports
SEMIRING_WEIGHTS = [
    ("plus_times", "inv_out"),
    ("plus_times", "unit"),
    ("min_plus", "length"),
    ("min_min", "unit"),
    ("max_times", "unit"),
]
#: reduces for which sharding must be bitwise (reassociation-exact ⊕)
BITWISE_ADDS = ("min",)


def _mesh(max_devices: int = 8) -> Mesh:
    """A 1-D mesh over up to ``max_devices`` of the available devices."""
    n = min(jax.device_count(), max_devices)
    return Mesh(np.asarray(jax.devices()[:n]), ("shards",))


def _graph(n=300, m=2000, seed=0, n_cap=None):
    src, dst = gnm_edges(n, m, seed=seed)
    return from_edges(src, dst, n_cap or n, m + 64)


def _values(semiring, n, seed=0):
    s = resolve_semiring(semiring)
    rng = np.random.default_rng(seed)
    if np.issubdtype(s.np_dtype, np.floating):
        return jnp.asarray(rng.random(n).astype(s.np_dtype))
    return jnp.asarray(rng.integers(0, n, n).astype(s.np_dtype))


def _assert_matches(out, ref, semiring):
    s = resolve_semiring(semiring)
    assert out.dtype == ref.dtype
    if s.add in BITWISE_ADDS:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_suite_sees_forced_host_devices():
    """Under the sharded CI job (8 forced host devices) the mesh really
    spans 8 devices; elsewhere this documents what the run covered."""
    mesh = _mesh()
    assert mesh.devices.size == min(jax.device_count(), 8)
    if jax.device_count() >= 8:
        assert mesh.devices.size == 8


# ------------------------------------------------------------- push parity
@pytest.mark.parametrize("semiring,weight", SEMIRING_WEIGHTS)
@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_sharded_push_matches_single_device(semiring, weight, backend):
    g = _graph()
    values = _values(semiring, g.node_capacity)
    ref = B.push(values, B.build_layout(g, weight=weight, semiring=semiring),
                 semiring=semiring, backend="segment_sum")
    mesh = _mesh()
    sharded = build_sharded_layout(g, mesh=mesh, weight=weight,
                                   semiring=semiring)
    out = B.push(values, sharded, semiring=semiring, backend=backend,
                 interpret=True)
    _assert_matches(out, ref, semiring)


@pytest.mark.parametrize("semiring,weight", SEMIRING_WEIGHTS)
def test_shard_loop_path_matches_single_device(semiring, weight):
    """mesh=None: the on-device shard loop is the reference semantics and
    keeps 8-way partitioning covered even on one device."""
    g = _graph(seed=3)
    values = _values(semiring, g.node_capacity, seed=4)
    ref = B.push(values, B.build_layout(g, weight=weight, semiring=semiring),
                 semiring=semiring, backend="segment_sum")
    sharded = build_sharded_layout(g, num_shards=8, weight=weight,
                                   semiring=semiring)
    out = B.push(values, sharded, semiring=semiring, backend="segment_sum")
    _assert_matches(out, ref, semiring)


def test_sharded_push_with_explicit_lengths_and_mask():
    """Per-edge lengths bake into the shards; masks filter the sharded
    sorted stream (the b_in boundary selection shape)."""
    g = _graph(n=200, m=1200, seed=5, n_cap=200)
    lengths = jnp.asarray(
        np.random.default_rng(6).uniform(0.5, 2.0, g.edge_capacity),
        jnp.float32)
    dist = _values("min_plus", 200, seed=7)
    single = B.build_layout(g, weight="length", semiring="min_plus",
                            lengths=lengths)
    sharded = build_sharded_layout(g, mesh=_mesh(), weight="length",
                                   semiring="min_plus", lengths=lengths)
    ref = B.push(dist, single, semiring="min_plus", backend="segment_sum")
    out = B.push(dist, sharded, semiring="min_plus", backend="segment_sum")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # masked: keep only edges into even receivers, in each stream's order
    m_single = (single.dst % 2) == 0
    m_sharded = (sharded.dst % 2) == 0
    ref_m = B.push(dist, single, semiring="min_plus", mask=m_single,
                   backend="segment_sum")
    out_m = B.push(dist, sharded, semiring="min_plus", mask=m_sharded,
                   backend="segment_sum")
    np.testing.assert_array_equal(np.asarray(out_m), np.asarray(ref_m))


def test_sharded_push_trace_time_guards():
    g = _graph(n=64, m=300, seed=8, n_cap=64)
    sharded = build_sharded_layout(g, num_shards=4, weight="unit",
                                   semiring="min_min")
    with pytest.raises(ValueError, match="sharded layout built for"):
        B.push(jnp.ones(64), sharded, semiring="plus_times")
    with pytest.raises(ValueError, match="mask must cover"):
        B.push(jnp.zeros(64, jnp.int32), sharded, semiring="min_min",
               mask=jnp.ones(64, bool), backend="segment_sum")
    with pytest.raises(ValueError, match="not in mesh"):
        build_sharded_layout(g, mesh=_mesh(), axes=("bogus",))
    with pytest.raises(ValueError, match="mesh= or num_shards="):
        build_sharded_layout(g)
    if jax.device_count() >= 2:  # with 1 device every shard count divides
        with pytest.raises(ValueError, match="multiple"):
            build_sharded_layout(g, mesh=_mesh(2), num_shards=3)


# ------------------------------------------------- fused query step parity
def _algo(name, num_iters=8):
    params = {"personalized-pagerank": dict(seeds=(1, 5))}.get(name, {})
    a = make_algorithm(name, **params)
    return a.__class__(**{**{f: getattr(a, f) for f in a.__dataclass_fields__},
                          "num_iters": num_iters})


@pytest.mark.parametrize("name", sorted(available_algorithms()))
def test_sharded_fused_query_step_matches_unsharded(name):
    """Full hot coverage: the summarized answer equals the exact sweep, so
    sharded-vs-unsharded disagreements cannot hide behind approximation."""
    g = _graph(n=250, m=1500, seed=10)
    algo = _algo(name)
    st0 = algo.init_state(g)
    st, _ = algo.exact(st0, g, backend="segment_sum")
    deg = jnp.copy(g.out_deg)
    act = jnp.copy(g.node_active)
    caps = dict(hot_node_capacity=g.node_capacity,
                hot_edge_capacity=g.edge_capacity)
    args = (g, st, deg, act, jnp.float32(0.0), jnp.float32(0.1))
    single = tuple(
        B.build_layout(g, weight=w, reverse=rev, semiring=s)
        for (w, rev, s) in map(B.normalize_layout_spec, algo.layout_specs))
    sharded = tuple(
        build_sharded_layout(g, mesh=_mesh(), weight=w, reverse=rev,
                             semiring=s)
        for (w, rev, s) in map(B.normalize_layout_spec, algo.layout_specs))
    ref_state, ref_stats = fused_query_step(
        *args, algo=algo, **caps, layouts=single, backend="segment_sum")
    out_state, out_stats = fused_query_step(
        *args, algo=algo, **caps, layouts=sharded, backend="segment_sum")
    assert not bool(ref_stats.used_fallback)
    assert int(out_stats.num_hot) == int(ref_stats.num_hot)
    assert int(out_stats.num_ek) == int(ref_stats.num_ek)
    for k in ref_state:
        _assert_matches(out_state[k], ref_state[k], algo.semiring)


@pytest.mark.parametrize("name", sorted(available_algorithms()))
def test_session_mesh_matches_unsharded_engine(name):
    """End to end through ``session(..., mesh=...)``: ingest a chunk, query,
    compare against the mesh-less engine."""
    src, dst = gnm_edges(220, 1300, seed=11)
    kw = {"sssp": dict(sources=(0,)),
          "personalized-pagerank": dict(seeds=(2,))}.get(name, {})
    with repro.session((src, dst), algorithm=name, num_iters=8, **kw) as ref, \
         repro.session((src, dst), algorithm=name, num_iters=8,
                       mesh=_mesh(), **kw) as sh:
        for s in (ref, sh):
            s.add_edges([1, 2, 3, 7], [4, 5, 6, 9])
        r_ref = ref.query()
        r_sh = sh.query()
        assert r_sh.action == r_ref.action
        _assert_matches(np.asarray(r_sh.scores), np.asarray(r_ref.scores),
                        sh.algorithm.semiring)
        # the sharded layout cache behaves like the single one, and its
        # arrays are placed across the mesh once per cache fill (not
        # re-distributed by every consuming shard_map)
        assert sh.engine.layout_builds == ref.engine.layout_builds
        lay = sh.engine.edge_layouts()[0]
        assert isinstance(lay, B.ShardedEdgeLayout)
        assert len(lay.src.sharding.device_set) == lay.mesh.devices.size


def test_sharded_plugin_path_traces_zero_push_coo():
    """The acceptance gate the dry-run enforces, pinned here: lowering the
    sharded ``fused_query_step`` touches no unsorted ``push_coo``."""
    g = _graph(n=251, m=1100, seed=12, n_cap=251)  # unique shapes => fresh trace
    algo = _algo("pagerank", num_iters=5)
    st = algo.init_state(g)
    mesh = _mesh()
    B.reset_trace_counts()
    fused_query_step(
        g, st, jnp.copy(g.out_deg), jnp.copy(g.node_active),
        jnp.float32(0.2), jnp.float32(0.1), algo=algo,
        hot_node_capacity=128, hot_edge_capacity=1024,
        backend="segment_sum", mesh=mesh)
    assert B.trace_count("push_coo") == 0
    # the mesh-less fallback (no layouts, no mesh) still goes through the
    # unsorted path — the counter is live, not vacuously zero
    B.reset_trace_counts()
    B.push_coo(jnp.ones(4), jnp.zeros(2, jnp.int32),
               jnp.ones(2, jnp.int32), 4)
    assert B.trace_count("push_coo") == 1

"""Tier-1 tests for the repro.analysis static-analysis layer.

Two halves, mirroring docs/analysis.md:

- **Fabricated violations** — one per rule family (injected f64, an
  unsorted edge-scale scatter, a host callback, an [E, N]
  materialization, an oversized all-gather, a retrace-per-iteration
  loop, a plugin holding a traced array, a hot-module host sync) must
  each be caught with a precise, actionable diagnostic.
- **Clean tree** — the shipped source and the committed baseline agree:
  the AST pass plus a hot-program subset of the jaxpr pass produce zero
  non-baseline findings.
"""

import ast
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import findings as F
from repro.analysis import ast_lint, hlo_audit, jaxpr_lint
from repro.analysis import programs as PR
from repro.analysis.retrace import TraceMonitor

REPO = Path(__file__).resolve().parents[1]
BASELINE = REPO / "benchmarks" / "analysis_baseline.json"


# ---------------------------------------------------------------------------
# finding / baseline model
# ---------------------------------------------------------------------------


def test_baseline_entries_require_reasons(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps(
        {"allow": [{"rule": "R1", "where": "prog:op", "reason": "  "}]}))
    with pytest.raises(ValueError, match="no reason"):
        F.load_baseline(p)


def test_missing_baseline_is_empty():
    assert F.load_baseline(None) == []
    assert F.load_baseline(Path("/nonexistent/baseline.json")) == []


def test_check_partitions_new_allowlisted_stale():
    found = [F.Finding("ast", "R1", "a:b", "d1"),
             F.Finding("ast", "R2", "c:d", "d2")]
    baseline = [F.BaselineEntry("R1", "a:b", "known"),
                F.BaselineEntry("R3", "e:f", "fixed long ago")]
    new, matched, stale = F.check(found, baseline)
    assert [f.key for f in new] == ["R2::c:d"]
    assert [f.key for f in matched] == ["R1::a:b"]
    assert [e.key for e in stale] == ["R3::e:f"]
    report = F.render_report(found, baseline, passes_run=["ast"])
    assert report["ok"] is False
    assert report["allowlisted"][0]["reason"] == "known"


def test_stale_scoped_to_passes_run():
    # an AST-only run must not declare the jaxpr allowlist obsolete: only
    # entries owned by a pass that actually ran can go stale
    baseline = [F.BaselineEntry("JXP-UNSORTED-SCATTER", "p:scatter", "known"),
                F.BaselineEntry("AST-HOST-SYNC", "f.py:g", "fixed")]
    _, _, stale = F.check([], baseline, passes_run=["ast"])
    assert [e.key for e in stale] == ["AST-HOST-SYNC::f.py:g"]
    _, _, stale = F.check([], baseline, passes_run=["ast", "jaxpr"])
    assert {e.rule for e in stale} == {"JXP-UNSORTED-SCATTER",
                                      "AST-HOST-SYNC"}
    assert F.pass_of_rule("HLO-ALLGATHER-BYTES") == "hlo"
    assert F.pass_of_rule("RT-RETRACE") == "retrace"
    assert F.pass_of_rule("UNKNOWN-RULE") is None


# ---------------------------------------------------------------------------
# fabricated jaxpr violations
# ---------------------------------------------------------------------------


def test_jaxpr_catches_injected_f64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        closed = jax.make_jaxpr(lambda x: x * 2.0)(
            jax.ShapeDtypeStruct((128,), jnp.float64))
    finally:
        jax.config.update("jax_enable_x64", prev)
    found = jaxpr_lint.lint_jaxpr(closed, program="fab[f64]")
    f64 = [f for f in found if f.rule == "JXP-F64"]
    assert f64, "injected float64 op not caught"
    assert "float64" in f64[0].detail and "fab[f64]" in f64[0].where


def test_jaxpr_catches_widening_convert():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        closed = jax.make_jaxpr(lambda x: x.astype(jnp.float64))(
            jax.ShapeDtypeStruct((64,), jnp.float32))
    finally:
        jax.config.update("jax_enable_x64", prev)
    found = jaxpr_lint.lint_jaxpr(closed, program="fab[widen]")
    assert any(f.rule == "JXP-WIDEN64" and "float32" in f.detail
               for f in found), "f32→f64 widening convert not caught"


def test_jaxpr_catches_unsorted_edge_scale_scatter():
    def unsorted_push(v, seg):
        return jax.ops.segment_sum(v, seg, num_segments=64)

    closed = jax.make_jaxpr(unsorted_push)(
        jnp.zeros((4096,), jnp.float32), jnp.zeros((4096,), jnp.int32))
    found = jaxpr_lint.lint_jaxpr(closed, program="fab[scatter]",
                                  edge_threshold=1024)
    hits = [f for f in found if f.rule == "JXP-UNSORTED-SCATTER"]
    assert hits, "unsorted edge-scale scatter-add not caught"
    assert "indices_are_sorted=False" in hits[0].detail
    assert "4096" in hits[0].detail  # names the measured update size


def test_jaxpr_scatter_rule_exempts_chunk_scale():
    # the same scatter under the edge-scale threshold (degree bookkeeping
    # over an apply chunk) is not the O(E) failure class
    def chunk_update(deg, idx):
        return deg.at[idx].add(1)

    closed = jax.make_jaxpr(chunk_update)(
        jnp.zeros((1024,), jnp.int32), jnp.zeros((64,), jnp.int32))
    found = jaxpr_lint.lint_jaxpr(closed, program="fab[chunk]",
                                  edge_threshold=8192)
    assert not [f for f in found if f.rule == "JXP-UNSORTED-SCATTER"]


def test_jaxpr_catches_host_callback():
    def with_callback(x):
        return jax.pure_callback(
            lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    closed = jax.make_jaxpr(with_callback)(jnp.zeros((8,), jnp.float32))
    found = jaxpr_lint.lint_jaxpr(closed, program="fab[callback]")
    assert any(f.rule == "JXP-CALLBACK" and "host round-trip" in f.detail
               for f in found), "pure_callback in jitted program not caught"


def test_jaxpr_catches_edge_node_materialization():
    closed = jax.make_jaxpr(
        lambda e, n: e[:, None] * n[None, :])(
        jnp.zeros((512,), jnp.float32), jnp.zeros((256,), jnp.float32))
    found = jaxpr_lint.lint_jaxpr(closed, program="fab[EN]",
                                  en_threshold=512 * 256 // 2)
    hits = [f for f in found if f.rule == "JXP-EDGE-NODE-MATERIALIZE"]
    assert hits, "[E, N] outer-product intermediate not caught"
    assert "131072" in hits[0].detail  # the materialized element count


# ---------------------------------------------------------------------------
# fabricated HLO violations
# ---------------------------------------------------------------------------

_FAKE_HLO = """HloModule fake

ENTRY %main (p0: f32[4096]) -> f32[131072] {
  %p0 = f32[4096]{0} parameter(0)
  %ag = f32[131072]{0} all-gather(f32[4096]{0} %p0), replica_groups={}, dimensions={0}
  ROOT %r = f32[131072]{0} add(f32[131072]{0} %ag, f32[131072]{0} %ag)
}
"""


def test_hlo_catches_oversized_all_gather():
    # budget: one 4-byte edge buffer at E_cap=16384 = 64 KiB; the fake
    # module all-gathers 512 KiB (an edge stream replicated 8×)
    budgets = hlo_audit.CollectiveBudgets(all_gather_max=4.0 * 16384)
    found = hlo_audit.audit_hlo_text(_FAKE_HLO, budgets, program="fab[ag]")
    assert len(found) == 1 and found[0].rule == "HLO-ALLGATHER-BYTES"
    assert "5.243e+05" in found[0].detail  # measured bytes
    assert "6.554e+04" in found[0].detail  # the budget it broke


def test_hlo_within_budget_is_clean():
    budgets = hlo_audit.CollectiveBudgets(all_gather_max=1e9)
    assert hlo_audit.audit_hlo_text(_FAKE_HLO, budgets,
                                    program="fab[ag]") == []


def test_hlo_catches_peak_temp():
    budgets = hlo_audit.CollectiveBudgets(temp_bytes_max=1e6)
    found = hlo_audit.audit_hlo_text(
        _FAKE_HLO, budgets, program="fab[temp]", temp_bytes=2e9)
    assert [f.rule for f in found] == ["HLO-TEMP-BYTES"]


def test_spec_budgets_are_ordered():
    spec = PR.GraphSpec()
    b = hlo_audit.budgets_for_spec(spec)
    # bucket exchange ≪ edge buffer ≪ temp scratch — the budgets separate
    assert b.all_to_all_max < b.all_gather_max < b.temp_bytes_max
    assert spec.edge_threshold == spec.edge_capacity // 2
    assert spec.en_threshold == spec.edge_capacity * spec.node_capacity // 2


# ---------------------------------------------------------------------------
# fabricated retrace violations
# ---------------------------------------------------------------------------


def test_retrace_catches_per_iteration_retrace():
    @jax.jit
    def step(x):
        return x * 2.0

    with TraceMonitor() as mon:
        step(jnp.zeros((4,), jnp.float32))
        warm = mon.snapshot()
        for i in range(3):
            # shape changes per iteration — a fabricated geometry drift
            step(jnp.zeros((5 + i,), jnp.float32))
    found = mon.check_warm(warm, scenario="fab-loop")
    hits = [f for f in found if "step" in f.where]
    assert hits and hits[0].rule == "RT-RETRACE"
    assert "3×" in hits[0].detail  # one retrace per post-warm-up iteration


def test_retrace_stable_loop_is_clean():
    @jax.jit
    def step(x):
        return x + 1.0

    with TraceMonitor() as mon:
        step(jnp.zeros((4,), jnp.float32))
        warm = mon.snapshot()
        for _ in range(3):
            step(jnp.zeros((4,), jnp.float32))
    assert mon.check_warm(warm, scenario="fab-stable") == []


def test_retrace_budget_contract():
    @jax.jit
    def leaky(x):
        return x - 1.0

    with TraceMonitor() as mon:
        for i in range(4):
            leaky(jnp.zeros((2 + i,), jnp.float32))
    found = mon.check({"leaky": 1}, scenario="fab-budget")
    hits = [f for f in found if "leaky" in f.where]
    assert hits and "4×" in hits[0].detail and "budget 1" in hits[0].detail


# ---------------------------------------------------------------------------
# fabricated AST violations
# ---------------------------------------------------------------------------


def _lint_source(rel: str, source: str, *, plugin_bases=None):
    linter = ast_lint._Linter(rel, source,
                              plugin_bases=plugin_bases
                              if plugin_bases is not None
                              else {"StreamingAlgorithm"})
    linter.visit(ast.parse(source))
    return linter.findings


def test_ast_catches_plugin_violations(tmp_path):
    bad = tmp_path / "fab_plugins.py"
    bad.write_text(
        "from dataclasses import dataclass\n"
        "import jax\n"
        "import jax.numpy as jnp\n"
        "class NotFrozen(StreamingAlgorithm):\n"
        "    pass\n"
        "@dataclass(frozen=True)\n"
        "class HoldsArray(StreamingAlgorithm):\n"
        "    weights: jax.Array\n"
        "@dataclass(frozen=True)\n"
        "class ArrayDefault(StreamingAlgorithm):\n"
        "    ranks = jnp.zeros(4)\n"
        "class Transitive(NotFrozen):\n"
        "    pass\n")
    found = ast_lint.lint_files([bad],
                                plugin_bases={"StreamingAlgorithm"})
    by_rule = {}
    for f in found:
        by_rule.setdefault(f.rule, []).append(f)
    frozen = by_rule.get("AST-PLUGIN-FROZEN", [])
    # NotFrozen, ArrayDefault? no — ArrayDefault is frozen; Transitive
    # inherits from a plugin subclass and is itself unfrozen
    assert {f.where.split(":")[-1] for f in frozen} == {
        "NotFrozen", "Transitive"}
    arrays = by_rule.get("AST-PLUGIN-ARRAY-FIELD", [])
    details = " | ".join(f.detail for f in arrays)
    assert "weights" in details and "jnp.zeros" in details


def test_ast_catches_hot_module_host_sync():
    # lint a fabricated source *as if* it were a hot module
    rel = "src/repro/core/fused.py"
    found = _lint_source(rel, (
        "import jax\n"
        "import numpy as np\n"
        "def hot_step(x):\n"
        "    x.block_until_ready()\n"
        "    a = float(jax.numpy.sum(x))\n"
        "    b = np.asarray(x)\n"
        "    c = jax.device_get(x)\n"
        "    return a, b, c\n"))
    rules = [f.rule for f in found]
    assert rules.count("AST-HOST-SYNC") == 4
    assert all("hot_step" in f.where for f in found)


def test_ast_inline_waiver_suppresses():
    rel = "src/repro/core/fused.py"
    found = _lint_source(rel, (
        "import jax\n"
        "def hot_step(x):\n"
        "    # analysis: allow(AST-HOST-SYNC): fabricated waiver test\n"
        "    return jax.device_get(x)\n"))
    assert found == []


def test_ast_catches_direct_segment_reduce_in_core():
    found = _lint_source("src/repro/core/fake_algo.py", (
        "import jax\n"
        "def sweep(v, seg):\n"
        "    return jax.ops.segment_sum(v, seg, num_segments=8)\n"))
    assert [f.rule for f in found] == ["AST-SEGMENT-REDUCE"]
    # ... and backend.py itself is the designated dispatch point
    assert _lint_source("src/repro/core/backend.py", (
        "import jax\n"
        "def push_coo(v, seg):\n"
        "    return jax.ops.segment_sum(v, seg, num_segments=8)\n")) == []


def test_ast_catches_hardcoded_kernel_geometry():
    found = _lint_source("src/repro/core/fused.py", (
        "from repro.kernels.spmv.ops import spmv_push\n"
        "def sweep(v, lay):\n"
        "    return spmv_push(v, lay, tile_n=256)\n"))
    assert [f.rule for f in found] == ["AST-KERNEL-GEOMETRY"]
    assert "tile_n=256" in found[0].detail


def test_ast_skip_list_excludes_lm_substrate():
    files = {p.as_posix() for p in ast_lint.iter_source_files()}
    assert not any("/models/" in f or "/train/" in f for f in files)
    assert any(f.endswith("core/backend.py") for f in files)


# ---------------------------------------------------------------------------
# clean tree vs the committed baseline
# ---------------------------------------------------------------------------


def test_ast_pass_clean_against_baseline():
    baseline = F.load_baseline(BASELINE)
    found = ast_lint.lint_files()
    new, _, _ = F.check(found, baseline)
    assert new == [], "new AST findings:\n" + "\n".join(map(str, new))


def test_jaxpr_pass_clean_on_hot_programs():
    baseline = F.load_baseline(BASELINE)
    spec = PR.GraphSpec()
    cat = [p for p in PR.catalog(spec)
           if p.name.startswith(("push[", "push_coo", "build_summary",
                                 "engine_apply"))]
    assert len(cat) >= 6
    found = jaxpr_lint.lint_programs(cat)
    new, matched, _ = F.check(found, baseline)
    assert new == [], "new jaxpr findings:\n" + "\n".join(map(str, new))
    # the unsorted fallback is *in* the baseline, not silently unflagged
    assert any(f.where.startswith("push_coo") for f in matched)
    # the sorted push programs themselves are finding-free
    assert not [f for f in found if f.where.startswith("push[")]


def test_rebalance_decision_stays_on_device():
    from repro.graph.generators import gnm_edges
    from repro.graph.graph import from_edges
    from repro.graph.partition import (rebalance_decision,
                                       rebalance_sharded_layout,
                                       shard_slots)

    src, dst = gnm_edges(64, 256, seed=3)
    state = from_edges(src, dst, 64, 1024)
    slots = jnp.asarray(shard_slots(state.edge_capacity, 4))
    should, imb = rebalance_decision(state, slots, jnp.float32(1.0))
    # the verdict pair is a device computation, not a host float
    assert isinstance(should, jax.Array) and should.dtype == jnp.bool_
    assert isinstance(imb, jax.Array) and imb.dtype == jnp.float32
    # the compat wrapper agrees with the raw decision
    _, rebalanced, imbalance = rebalance_sharded_layout(
        state, num_shards=4, slots=slots, threshold=1.0)
    assert rebalanced == bool(should)
    assert imbalance == pytest.approx(float(imb))

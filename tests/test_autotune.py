"""Autotuned geometry + quantized weights acceptance.

Four contracts from the kernel-perf redesign:

- **parity grid**: both push kernel variants (one-hot matmul sum, rank-
  stream masked reduce) produce backend-parity results at *every*
  candidate ``(tile_n, chunk)`` geometry, for all four registered
  semirings — tuning can change speed, never results;
- **tuner semantics**: ``cached`` mode is deterministic and never writes
  the cache (it holds measured/JSON-loaded tunings only), ``full`` mode
  measures once per key and is skipped by cache hits, and the JSON cache
  round-trips;
- **bf16 edge weights**: storage-only narrowing — f32 accumulation keeps
  plus_times within quantization tolerance and min_plus bitwise when the
  lengths are bf16-representable; non-f32 algebras reject the option;
- **roofline gate**: the CI byte-volume check shares ``modeled_push_cost``
  with the tuner (they can never disagree), the committed baseline file
  verifies clean, and a fabricated regression trips the AssertionError.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as B
from repro.core.semiring import resolve_semiring
from repro.graph import from_edges
from repro.graph.generators import gnm_edges
from repro.kernels.spmv import autotune as AT

SEMIRING_WEIGHT = [("plus_times", "inv_out"), ("min_plus", "length"),
                   ("min_min", "unit"), ("max_times", "unit")]
GRID = [(t, c) for t in (128, 256, 512) for c in (128, 512, 1024)]


def _graph(n=300, m=1500, seed=0):
    src, dst = gnm_edges(n, m, seed=seed)
    return from_edges(src, dst, n, m + 64)


def _values(s, n, seed):
    rng = np.random.default_rng(seed)
    if np.issubdtype(s.np_dtype, np.floating):
        v = rng.random(n).astype(s.np_dtype)
        if s.name == "min_plus":
            v = np.where(rng.random(n) < 0.1, v, np.inf).astype(s.np_dtype)
        return jnp.asarray(v)
    return jnp.asarray(rng.integers(0, n, n).astype(s.np_dtype))


# ------------------------------------------------ geometry parity grid
@pytest.mark.parametrize("tile_n,chunk", GRID)
@pytest.mark.parametrize("name,weight", SEMIRING_WEIGHT)
def test_geometry_parity_grid(name, weight, tile_n, chunk):
    s = resolve_semiring(name)
    g = _graph(seed=1)
    layout = B.build_layout(g, weight=weight, semiring=name,
                            tile_n=tile_n, chunk=chunk)
    assert layout.tile_n == tile_n and layout.tile_chunk == chunk
    v = _values(s, 300, seed=2)
    ref = B.push(v, layout, semiring=name, backend="segment_sum")
    out = B.push(v, layout, semiring=name, backend="pallas", interpret=True)
    if s.add == "sum":
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
    else:  # min/max reduces are reassociation-exact
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("name,weight", [("plus_times", "inv_out"),
                                         ("min_plus", "length")])
def test_geometry_parity_batched(name, weight):
    s = resolve_semiring(name)
    g = _graph(seed=3)
    layout = B.build_layout(g, weight=weight, semiring=name,
                            tile_n=128, chunk=256)
    rng = np.random.default_rng(4)
    v = jnp.asarray(rng.random((3, 300)).astype(np.float32))
    ref = B.push(v, layout, semiring=name, backend="segment_sum")
    out = B.push(v, layout, semiring=name, backend="pallas", interpret=True)
    if s.add == "sum":
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("reduce", ["sum", "min"])
def test_double_buffer_flag_is_result_invariant(reduce):
    """``double_buffer=True`` only changes how chunk loads are staged —
    results must be bit-identical to the single-buffered path."""
    key = AT.TuneKey(e_pad=4096, n=512, b=1, dtype="float32",
                     reduce=reduce, platform=jax.default_backend())
    contrib, dstp, rank, tile_start, num_tiles = AT._synthetic_args(
        key, 512, 128)
    from repro.kernels.spmv.kernel import spmv_push, spmv_reduce_push
    kw = dict(num_tiles=num_tiles, tile_n=128, chunk=512, interpret=True)
    if reduce == "sum":
        a = spmv_push(contrib, dstp, tile_start, double_buffer=False, **kw)
        b = spmv_push(contrib, dstp, tile_start, double_buffer=True, **kw)
    else:
        a = spmv_reduce_push(contrib, dstp, rank, tile_start, op=reduce,
                             double_buffer=False, **kw)
        b = spmv_reduce_push(contrib, dstp, rank, tile_start, op=reduce,
                             double_buffer=True, **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------ tuner semantics
def _key(reduce="sum", **over):
    kw = dict(e_pad=8192, n=1024, b=1, dtype="float32", reduce=reduce,
              platform=jax.default_backend())
    kw.update(over)
    return AT.TuneKey(**kw)


def test_tune_off_returns_defaults_without_cache_interaction():
    AT.clear_cache()
    assert AT.tune(_key(), "off") == (AT.TILE_N, AT.CHUNK)
    assert AT.cache_entries() == {} and AT.run_count() == 0


def test_tune_cached_is_deterministic_and_does_not_write_cache():
    AT.clear_cache()
    first = AT.tune(_key(), "cached")
    assert first == AT.tune(_key(), "cached")
    assert first == AT.candidates(_key())[0]  # the analytic argmin
    # cached mode must not populate the cache: a later "full" run still
    # gets to time candidates
    assert AT.cache_entries() == {}
    measured = AT.tune(_key(), "full", measure_top=2)
    assert AT.run_count() == 1
    assert measured in AT.candidates(_key())[:2]
    AT.clear_cache()


def test_tune_full_cache_hit_skips_timing():
    AT.clear_cache()
    best = AT.tune(_key(reduce="min"), "full", measure_top=2)
    assert AT.run_count() == 1
    again = AT.tune(_key(reduce="min"), "full", measure_top=2)
    assert again == best
    assert AT.run_count() == 1          # hit — no second measurement
    assert AT.cache_hits() == 1
    AT.clear_cache()


def test_cache_save_load_round_trip(tmp_path):
    AT.clear_cache()
    best = AT.tune(_key(), "full", measure_top=2)
    path = tmp_path / "cache.json"
    AT.save_cache(path)
    AT.clear_cache()
    assert AT.load_cache(path) == 1
    # the loaded entry answers cached mode with zero measurements
    assert AT.tune(_key(), "cached") == best
    assert AT.run_count() == 0
    assert AT.load_cache(tmp_path / "missing.json") == 0
    AT.clear_cache()


def test_candidates_are_vmem_pruned_and_model_ranked():
    key = _key(b=64, reduce="min")      # wide batch inflates the working set
    cands = AT.candidates(key)
    assert 0 < len(cands) <= len(AT.TILE_N_CANDIDATES) * len(
        AT.CHUNK_CANDIDATES)
    for tile_n, chunk in cands:
        cost = AT.modeled_push_cost(e_pad=key.e_pad, n=key.n, b=key.b,
                                    reduce=key.reduce, tile_n=tile_n,
                                    chunk=chunk)
        assert cost.vmem_bytes <= AT.VMEM_LIMIT_BYTES
    bounds = [AT.modeled_push_cost(e_pad=key.e_pad, n=key.n, b=key.b,
                                   reduce=key.reduce, tile_n=t,
                                   chunk=c).bound_time_s
              for t, c in cands]
    assert bounds == sorted(bounds)


def test_tune_key_string_round_trip():
    key = _key(b=8, reduce="max")
    assert AT.TuneKey.from_str(key.as_str()) == key


# ------------------------------------------------- bf16 edge weights
def test_bf16_weights_plus_times_within_quantization_tolerance():
    g = _graph(seed=5)
    v = jnp.asarray(np.random.default_rng(6).random(300).astype(np.float32))
    full = B.build_layout(g, weight="inv_out")
    comp = B.build_layout(g, weight="inv_out", weight_dtype="bfloat16")
    assert comp.weight.dtype == jnp.bfloat16
    ref = B.push(v, full, backend="segment_sum")
    out = B.push(v, comp, backend="segment_sum")
    # bf16 has ~3 decimal digits; accumulation stays f32
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-2, atol=1e-3)
    pal = B.push(v, comp, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(out),
                               rtol=1e-5, atol=1e-6)


def test_bf16_weights_min_plus_bitwise_for_representable_lengths():
    rng = np.random.default_rng(7)
    src, dst = gnm_edges(300, 1500, seed=8)
    lengths = rng.choice([0.25, 0.5, 1.0, 2.0], len(src)).astype(np.float32)
    g = from_edges(src, dst, 300, len(src) + 64, weights=lengths)
    v = _values(resolve_semiring("min_plus"), 300, seed=9)
    full = B.build_layout(g, weight="length", semiring="min_plus")
    comp = B.build_layout(g, weight="length", semiring="min_plus",
                          weight_dtype="bfloat16")
    ref = B.push(v, full, semiring="min_plus", backend="segment_sum")
    out = B.push(v, comp, semiring="min_plus", backend="segment_sum")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_bf16_weights_rejected_for_non_f32_semirings():
    g = _graph(seed=10)
    with pytest.raises(ValueError, match="weight_dtype"):
        B.build_layout(g, weight="unit", semiring="min_min",
                       weight_dtype="bfloat16")


# -------------------------------------------- engine + session threading
def test_session_threads_autotune_and_weight_dtype():
    from repro import api

    src, dst = gnm_edges(256, 1200, seed=11)
    AT.clear_cache()
    sess = api.session((src, dst), "pagerank", node_capacity=256,
                       edge_capacity=1536, hot_node_capacity=256,
                       hot_edge_capacity=1536, autotune="cached",
                       weight_dtype="bfloat16")
    eng = sess.engine
    assert eng.config.autotune == "cached"
    (layout,) = eng.edge_layouts()
    # cached mode resolved a concrete geometry and stamped it on the layout
    assert (layout.tile_n, layout.tile_chunk) == AT.tune_for_push(
        edge_capacity=1536, num_segments=256, mode="cached")
    assert layout.weight.dtype == jnp.bfloat16
    assert eng.autotune_runs == 0       # cached mode never measures
    AT.clear_cache()


def test_engine_weight_dtype_skipped_for_integer_algebra():
    from repro.core.engine import EngineConfig, VeilGraphEngine

    eng = VeilGraphEngine(EngineConfig(
        node_capacity=128, edge_capacity=256, hot_node_capacity=128,
        hot_edge_capacity=256, weight_dtype="bfloat16"))
    # min_min is int32: compression is silently skipped, not an error
    assert eng._weight_dtype_for("min_min") is None
    assert eng._weight_dtype_for("plus_times") == "bfloat16"


# ------------------------------------------------------- roofline gate
def test_roofline_gate_shares_the_tuner_cost_model():
    from repro.launch import roofline as RL

    rec = RL.push_roofline_check(edge_capacity=10_000, num_segments=2_048,
                                 reduce="min", tile_n=128, chunk=256)
    e_pad = (10_000 // AT.CHUNK + 2) * AT.CHUNK
    cost = AT.modeled_push_cost(e_pad=e_pad, n=2_048, reduce="min",
                                tile_n=128, chunk=256)
    assert rec["hbm_bytes"] == cost.hbm_bytes
    assert rec["flops"] == cost.flops
    assert rec["dominant"] in ("memory", "compute")


def test_committed_roofline_baseline_verifies_clean():
    from pathlib import Path
    from repro.launch import roofline as RL

    path = (Path(__file__).resolve().parents[1] / "benchmarks" /
            "roofline_baseline.json")
    checks = RL.check_push_baselines(path)
    assert len(checks) >= 5


def test_roofline_gate_trips_on_regression():
    from repro.launch import roofline as RL

    rec = RL.push_roofline_check(edge_capacity=10_000, num_segments=2_048)
    shrunk = dict(rec, hbm_bytes=rec["hbm_bytes"] / 1.25)
    with pytest.raises(AssertionError, match="regressed"):
        RL.push_roofline_check(edge_capacity=10_000, num_segments=2_048,
                               baseline=shrunk)

"""Semiring-generic propagation: algebra specs, backend parity, fallbacks.

The acceptance contract of the (⊕, ⊗) redesign:

- every registered semiring pushes identically on the pallas (interpret)
  and segment backends, including non-float dtypes and custom tile
  geometry;
- the unsorted ``push_coo`` fallback (the sharded dry-run's cost model) is
  pinned to the sorted ``push`` primitive across weight/mask combinations
  so the two cost models cannot drift;
- the new segment-min/max fallbacks match a pure-numpy reference on
  property-sampled random graphs;
- mis-matched layouts/semirings fail loudly at trace time.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.core import backend as B
from repro.core.semiring import (MIN_MIN, MIN_PLUS, PLUS_TIMES, Semiring,
                                 available_semirings, resolve_semiring)
from repro.graph import from_edges
from repro.graph.csr import gather_push, sort_by_dst
from repro.graph.generators import gnm_edges

TOL = dict(rtol=1e-5, atol=1e-6)


def _graph(n=300, m=2000, seed=0, n_cap=None):
    src, dst = gnm_edges(n, m, seed=seed)
    return from_edges(src, dst, n_cap or n, m + 64)


def _values(s: Semiring, n: int, seed: int):
    rng = np.random.default_rng(seed)
    if np.issubdtype(s.np_dtype, np.floating):
        v = rng.random(n).astype(s.np_dtype)
        if s.name == "min_plus":  # distances: a few sources, rest +inf
            v = np.where(rng.random(n) < 0.1, v, np.inf).astype(s.np_dtype)
        return jnp.asarray(v)
    return jnp.asarray(rng.integers(0, n, n).astype(s.np_dtype))


def _numpy_push(s: Semiring, src, dst, w, values, n, mask=None):
    """Reference ⊕/⊗ over an explicit edge list."""
    out = np.full(n, s.zero, s.np_dtype)
    combine = {"times": lambda a, b: a * b,
               "plus": lambda a, b: a + b,
               "min": np.minimum}[s.mul]
    reduce_ = {"sum": lambda a, b: a + b, "min": np.minimum,
               "max": np.maximum}[s.add]
    for i, (a, b) in enumerate(zip(src, dst)):
        if mask is not None and not mask[i]:
            continue
        out[b] = reduce_(out[b], combine(values[a], w[i]))
    return out


# ----------------------------------------------------------- the algebra
def test_semiring_identities_and_registry():
    assert {"plus_times", "min_plus", "min_min",
            "max_times"} <= set(available_semirings())
    pt = resolve_semiring("plus_times")
    assert pt is PLUS_TIMES and pt.zero == 0.0 and pt.one == 1.0
    mp = resolve_semiring("min_plus")
    assert mp.zero == np.inf and mp.one == 0.0
    mm = resolve_semiring("min_min")
    assert mm.np_dtype == np.int32
    assert mm.zero == np.iinfo(np.int32).max  # int "+inf"
    assert mm.one == np.iinfo(np.int32).max   # ⊗=min's identity
    mt = resolve_semiring("max_times")
    assert mt.zero == -np.inf and mt.one == 1.0
    # instances resolve to themselves; None means plus_times
    assert resolve_semiring(mp) is mp
    assert resolve_semiring(None) is PLUS_TIMES
    with pytest.raises(KeyError):
        resolve_semiring("tropical-nonsense")
    with pytest.raises(ValueError):
        Semiring("bogus", "avg", "times")
    with pytest.raises(ValueError):
        Semiring("bogus", "sum", "divide")


@pytest.mark.parametrize("name", ["plus_times", "min_plus", "min_min",
                                  "max_times"])
def test_combine_matches_identity_laws(name):
    s = resolve_semiring(name)
    v = _values(s, 64, seed=3)
    one = jnp.full((64,), s.one, s.np_dtype)
    np.testing.assert_array_equal(np.asarray(s.combine(v, one)),
                                  np.asarray(v))


# --------------------------------------------------- backend parity: push
@pytest.mark.parametrize("name,weight", [
    ("plus_times", "inv_out"), ("plus_times", "unit"),
    ("min_plus", "length"), ("min_plus", "unit"),
    ("min_min", "unit"), ("max_times", "unit"),
])
def test_push_backend_parity_per_semiring(name, weight):
    s = resolve_semiring(name)
    g = _graph(n=257, m=1200, seed=1, n_cap=257)  # non-multiple-of-tile N
    layout = B.build_layout(g, weight=weight, semiring=name)
    v = _values(s, 257, seed=2)
    ref = B.push(v, layout, semiring=name, backend="segment_sum")
    out = B.push(v, layout, semiring=name, backend="pallas", interpret=True)
    if s.add == "sum":
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    else:  # min/max reduces are reassociation-exact
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # and against the edge-list oracle
    mask = np.asarray(g.edge_mask())
    w = np.full(mask.shape, s.one, s.np_dtype)
    if weight == "inv_out":
        from repro.graph.graph import inv_out_degree
        w = np.asarray(inv_out_degree(g))[np.asarray(g.src)]
    elif weight == "length":
        w = np.ones(mask.shape, s.np_dtype)
    oracle = _numpy_push(s, np.asarray(g.src), np.asarray(g.dst), w,
                         np.asarray(v), 257, mask=mask)
    np.testing.assert_allclose(np.asarray(ref), oracle, **TOL)


@pytest.mark.parametrize("name", ["min_plus", "min_min"])
def test_reduce_push_custom_tile_geometry(name):
    s = resolve_semiring(name)
    weight = "length" if name == "min_plus" else "unit"
    g = _graph(n=257, m=900, seed=2, n_cap=257)
    v = _values(s, 257, seed=5)
    for chunk in (256, 512):
        layout = B.build_layout(g, weight=weight, semiring=name, chunk=chunk)
        ref = B.push(v, layout, semiring=name, backend="segment_sum")
        for tile_n in (64, 128, 256):
            out = B.push(v, layout, semiring=name, backend="pallas",
                         tile_n=tile_n, chunk=chunk, interpret=True)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_reduce_push_empty_graph_gives_identity(backend):
    g = from_edges(np.zeros(0, np.int32), np.zeros(0, np.int32), 256, 64)
    layout = B.build_layout(g, weight="length", semiring="min_plus")
    out = B.push(jnp.zeros(256), layout, semiring="min_plus",
                 backend=backend, interpret=True)
    assert bool(jnp.all(jnp.isposinf(out)))  # ⊕-identity everywhere


def test_explicit_edge_lengths_flow_through_sort():
    """weight='length' with explicit per-slot lengths survives the dst sort."""
    g = _graph(n=64, m=400, seed=7, n_cap=64)
    rng = np.random.default_rng(8)
    lengths = jnp.asarray(rng.random(g.edge_capacity).astype(np.float32))
    layout = B.build_layout(g, weight="length", semiring="min_plus",
                            lengths=lengths)
    v = _values(MIN_PLUS, 64, seed=9)
    out = B.push(v, layout, semiring="min_plus", backend="segment_sum")
    mask = np.asarray(g.edge_mask())
    oracle = _numpy_push(MIN_PLUS, np.asarray(g.src), np.asarray(g.dst),
                         np.asarray(lengths), np.asarray(v), 64, mask=mask)
    np.testing.assert_array_equal(np.asarray(out), oracle)


# ------------------------------------- push_coo pinned to push (satellite)
@pytest.mark.parametrize("name,weight", [
    ("plus_times", "inv_out"), ("plus_times", "unit"),
    ("min_plus", "length"), ("min_min", "unit"), ("max_times", "unit"),
])
@pytest.mark.parametrize("masked", [False, True])
def test_push_coo_matches_push(name, weight, masked):
    """The unsorted fallback (sharded dry-run cost model) must agree with
    the sorted primitive for every weight/mask combination."""
    s = resolve_semiring(name)
    g = _graph(n=200, m=1500, seed=11, n_cap=200)
    layout = B.build_layout(g, weight=weight, semiring=name)
    v = _values(s, 200, seed=12)
    edge_mask = g.edge_mask()

    # the same per-edge operand in unsorted order
    if weight == "inv_out":
        from repro.graph.graph import inv_out_degree
        w_coo = inv_out_degree(g)[g.src]
    elif weight == "length":
        w_coo = jnp.ones((g.edge_capacity,), s.np_dtype)
    else:
        w_coo = jnp.full((g.edge_capacity,), s.one, s.np_dtype)

    if masked:
        # an E_B-style endpoint-defined mask, expressible in both orders
        hot = jnp.asarray(
            np.random.default_rng(13).random(200) < 0.5)
        coo_mask = edge_mask & (~hot[g.src]) & hot[g.dst]
        sorted_mask = (~hot[layout.src]) & hot[jnp.minimum(layout.dst, 199)]
    else:
        coo_mask = edge_mask
        sorted_mask = None

    ref = B.push(v, layout, semiring=name, mask=sorted_mask,
                 backend="segment_sum")
    out = B.push_coo(v, g.src, g.dst, 200, weight=w_coo, mask=coo_mask,
                     semiring=name)
    if s.add == "sum":
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    else:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ------------------------------ property-based segment-min/max (satellite)
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), density=st.floats(0.002, 0.05),
       name=st.sampled_from(["min_plus", "min_min", "max_times"]))
def test_segment_reduce_fallback_property(seed, density, name):
    """gather_push's segment-min/max on sorted layouts == numpy loop."""
    s = resolve_semiring(name)
    rng = np.random.default_rng(seed)
    n = 128
    m = max(1, int(density * n * n))
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    g = from_edges(src, dst, n, m + 8)
    se = sort_by_dst(g)
    v = _values(s, n, seed=seed + 1)
    w = jnp.asarray(rng.random(se.src.shape[0]).astype(np.float32)) \
        if np.issubdtype(s.np_dtype, np.floating) else \
        jnp.asarray(rng.integers(0, n, se.src.shape[0]).astype(np.int32))
    out = gather_push(se, v, n, weight=w, semiring=s)
    oracle = _numpy_push(s, np.asarray(se.src), np.asarray(se.dst),
                         np.asarray(w), np.asarray(v), n,
                         mask=np.asarray(se.valid))
    np.testing.assert_allclose(np.asarray(out), oracle, **TOL)


# ----------------------------------------------------- trace-time guards
def test_custom_int_sum_semiring_parity_or_loud_failure():
    """A user-registered int32 sum semiring stays exact on the segment
    backend; the f32-matmul pallas path refuses instead of silently
    casting (dtype parity between backends, or a loud error)."""
    from repro.core.semiring import register_semiring
    s = register_semiring(Semiring("count_paths", "sum", "times", "int32"))
    g = _graph(n=64, m=300, seed=24, n_cap=64)
    layout = B.build_layout(g, weight="unit", semiring="count_paths")
    v = jnp.ones(64, jnp.int32)
    out = B.push(v, layout, semiring="count_paths", backend="segment_sum")
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(g.in_deg))  # unit counts = in-degree
    with pytest.raises(NotImplementedError, match="segment_sum"):
        B.push(v, layout, semiring="count_paths", backend="pallas",
               interpret=True)


def test_layout_semiring_mismatch_rejected():
    g = _graph(n=64, m=300, seed=20, n_cap=64)
    unit_mm = B.build_layout(g, weight="unit", semiring="min_min")
    with pytest.raises(ValueError, match="semiring"):
        B.push(jnp.ones(64), unit_mm)  # plus_times over a min_min layout
    with pytest.raises(ValueError, match="semiring"):
        B.push(jnp.ones(64), unit_mm, semiring="min_plus",
               backend="segment_sum")
    with pytest.raises(ValueError, match="inv_out"):
        B.build_layout(g, weight="inv_out", semiring="min_plus")
    with pytest.raises(ValueError, match="lengths"):
        B.build_layout(g, weight="unit", semiring="min_plus",
                       lengths=jnp.ones(g.edge_capacity))
    with pytest.raises(ValueError, match="weight mode"):
        B.build_layout(g, weight="distance", semiring="min_plus")
    with pytest.raises(ValueError, match="layout spec"):
        B.normalize_layout_spec(("unit",))


def test_build_summary_rejects_inv_out_on_min_semiring():
    from repro.core.pagerank import build_summary
    g = _graph(n=64, m=300, seed=21, n_cap=64)
    hot = jnp.ones(64, bool)
    with pytest.raises(ValueError, match="inv_out"):
        build_summary(g, jnp.ones(64), hot, hot_node_capacity=64,
                      hot_edge_capacity=512, semiring="min_plus")


def test_summary_layout_rejects_mismatched_semiring():
    """A plus_times consumer over +∞-baked min-semiring buffers would
    silently NaN — the summary records its algebra and the layout builder
    checks it at trace time."""
    from repro.core.pagerank import build_summary
    g = _graph(n=64, m=300, seed=23, n_cap=64)
    hot = jnp.ones(64, bool)
    s = build_summary(g, jnp.zeros(64), hot, hot_node_capacity=64,
                      hot_edge_capacity=512, weight="length",
                      semiring="min_plus")
    assert s.semiring == "min_plus" and s.weight_mode == "length"
    with pytest.raises(ValueError, match="baked for"):
        B.summary_layout(s)  # defaults to plus_times
    B.summary_layout(s, semiring="min_plus")  # matching algebra passes


# ------------------------------------------- session ingestion (satellite)
def test_add_edges_rejects_mismatched_shapes():
    src, dst = gnm_edges(50, 200, seed=22)
    with repro.session((src, dst), algorithm="pagerank") as s:
        with pytest.raises(ValueError, match="equal length"):
            s.add_edges([0, 1, 2], [3, 4])
        with pytest.raises(ValueError, match="1-D"):
            s.add_edges(np.zeros((2, 2), np.int32), np.zeros((2, 2), np.int32))
        with pytest.raises(ValueError, match="equal length"):
            s.remove_edges([0, 1], [2])
        # a valid call still goes through after the failed ones
        s.add_edges([0], [1])
        assert s.engine.pending_updates == 1

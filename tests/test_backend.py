"""Unified propagation backend: pallas (interpret) vs segment_sum parity.

Every sweep family — exact, summarized, and the big-vertex pass — must
produce the same numbers on both backends for every registered algorithm;
the engine must sort the edge layout at most once per applied update batch.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import backend as B
from repro.core.algorithm import available_algorithms, make_algorithm
from repro.core.pagerank import build_summary, pagerank, summarized_pagerank
from repro.graph import from_edges
from repro.graph.csr import gather_push, sort_by_dst
from repro.graph.generators import gnm_edges
from repro.graph.graph import find_edge_slots, remove_edges_by_slot

TOL = dict(rtol=1e-5, atol=1e-6)


def _algo(name):
    # registry factories needing parameters get deterministic ones here
    params = {"personalized-pagerank": dict(seeds=(1, 5))}.get(name, {})
    a = make_algorithm(name, **params)
    # shrink sweeps so interpret-mode kernels stay fast
    return a.__class__(**{**{f: getattr(a, f) for f in a.__dataclass_fields__},
                          "num_iters": 8})


def _graph(n=300, m=2000, seed=0, n_cap=None):
    src, dst = gnm_edges(n, m, seed=seed)
    return from_edges(src, dst, n_cap or n, m + 64)


def _layouts(g, algo):
    return tuple(B.build_layout(g, weight=w, reverse=rev, semiring=s)
                 for (w, rev, s) in map(B.normalize_layout_spec,
                                        algo.layout_specs))


def _hot(n_cap, seed=0, frac=0.5):
    return jnp.asarray(np.random.default_rng(seed).random(n_cap) < frac)


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("name", sorted(available_algorithms()))
def test_exact_sweep_backend_parity(name):
    g = _graph()
    algo = _algo(name)
    st0 = algo.init_state(g)
    layouts = _layouts(g, algo)
    ref, _ = algo.exact(st0, g, layouts=layouts, backend="segment_sum")
    out, _ = algo.exact(st0, g, layouts=layouts, backend="pallas")
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   **TOL)
    # and against the no-layout (unsorted COO) reference path
    base, _ = algo.exact(st0, g, layouts=None, backend="segment_sum")
    for k in ref:
        np.testing.assert_allclose(np.asarray(ref[k]), np.asarray(base[k]),
                                   **TOL)


@pytest.mark.parametrize("name", sorted(available_algorithms()))
def test_summarized_sweep_backend_parity(name):
    g = _graph()
    algo = _algo(name)
    st0 = algo.init_state(g)
    st, _ = algo.exact(st0, g)
    hot = _hot(g.node_capacity)
    layouts = _layouts(g, algo)
    caps = dict(hot_node_capacity=256, hot_edge_capacity=1024)
    summaries = algo.build_summaries(st, g, hot, **caps)
    # the big-vertex pass with a cached layout must match the unsorted one
    with_layout = algo.build_summaries(
        st, g, hot, **caps, layouts=layouts, backend="segment_sum")
    for s, sl in zip(summaries, with_layout):
        assert not bool(s.overflow)
        np.testing.assert_allclose(np.asarray(sl.b_in), np.asarray(s.b_in),
                                   **TOL)
    ref, _ = algo.summarized(st, g, summaries, backend="segment_sum")
    out, _ = algo.summarized(st, g, summaries, backend="pallas")
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   **TOL)


def test_push_parity_custom_tile_geometry():
    """tile_n/chunk are parameters, not module constants."""
    g = _graph(n=257, m=900, seed=2, n_cap=257)  # non-multiple-of-tile N
    layout = B.build_layout(g, weight="inv_out")
    r = jnp.asarray(np.random.default_rng(3).random(257).astype(np.float32))
    ref = B.push(r, layout, backend="segment_sum")
    for tile_n, chunk in [(128, 256), (64, 512), (256, 128)]:
        out = B.push(r, layout, backend="pallas", tile_n=tile_n, chunk=chunk,
                     interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_gather_push_is_the_sorted_fallback():
    """csr.gather_push == unsorted segment_sum on the sorted layout."""
    g = _graph(n=200, m=1500, seed=4, n_cap=200)
    se = sort_by_dst(g)
    vals = jnp.asarray(np.random.default_rng(5).random(200).astype(np.float32))
    out = gather_push(se, vals, 200)
    ref = jax.ops.segment_sum(
        jnp.where(g.edge_mask(), vals[g.src], 0.0), g.dst, num_segments=200)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    # weighted form (what backend.push uses)
    w = jnp.asarray(np.random.default_rng(6).random(se.src.shape[0]), jnp.float32)
    out_w = gather_push(se, vals, 200, weight=w)
    ref_w = jax.ops.segment_sum(
        jnp.where(se.valid, vals[se.src] * w, 0.0),
        jnp.minimum(se.dst, 199), num_segments=200, indices_are_sorted=True)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(ref_w), **TOL)


# -------------------------------------------------------------- edge cases
@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_push_empty_graph(backend):
    g = from_edges(np.zeros(0, np.int32), np.zeros(0, np.int32), 256, 64)
    layout = B.build_layout(g, weight="inv_out")
    out = B.push(jnp.ones(256), layout, backend=backend, interpret=True)
    assert out.shape == (256,)
    assert float(jnp.abs(out).max()) == 0.0


@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_push_ignores_tombstoned_edges(backend):
    """Removed edges sort into the padding region and contribute nothing."""
    g = _graph(n=128, m=700, seed=7, n_cap=128)
    slots = find_edge_slots(g, np.asarray(g.src)[:200], np.asarray(g.dst)[:200])
    g2 = remove_edges_by_slot(g, jnp.asarray(slots))
    layout = B.build_layout(g2, weight="inv_out")
    r = jnp.asarray(np.random.default_rng(8).random(128).astype(np.float32))
    out = B.push(r, layout, backend=backend, interpret=True)
    from repro.graph.graph import inv_out_degree
    ref = jax.ops.segment_sum(
        jnp.where(g2.edge_mask(), (r * inv_out_degree(g2))[g2.src], 0.0),
        g2.dst, num_segments=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_summarized_overflow_flag_and_no_crash(backend):
    g = _graph(n=300, m=2000, seed=9)
    r0, _ = pagerank(g, num_iters=5)
    hot = jnp.ones(g.node_capacity, bool)
    s = build_summary(g, r0, hot, hot_node_capacity=32, hot_edge_capacity=64)
    assert bool(s.overflow)
    # the result is discarded on overflow, but the sweep must still run
    ranks, _ = summarized_pagerank(s, r0, num_iters=3, backend=backend)
    assert ranks.shape == r0.shape
    assert bool(jnp.all(jnp.isfinite(ranks)))


def test_summary_ek_buffer_is_destination_sorted():
    g = _graph()
    r0, _ = pagerank(g, num_iters=5)
    hot = _hot(g.node_capacity, seed=1)
    s = build_summary(g, r0, hot, hot_node_capacity=256,
                      hot_edge_capacity=1024)
    ek_dst = np.asarray(s.ek_dst)
    assert (np.diff(ek_dst) >= 0).all()
    n_ek = int(s.num_ek)
    assert (ek_dst[n_ek:] == 256).all()  # padding sentinel sorts last
    ro = np.asarray(s.ek_row_offsets)
    assert ro.shape == (257,)
    assert ro[0] == 0 and ro[-1] == n_ek
    for z in (0, 17, 255):
        assert (ek_dst[ro[z]:ro[z + 1]] == z).all()


# ------------------------------------------------------- engine-level cache
def test_engine_reuses_sorted_layout_across_queries():
    src, dst = gnm_edges(400, 2500, seed=11)
    with repro.session((src, dst), algorithm="pagerank") as s:
        eng = s.engine
        assert eng.layout_builds == 1  # built for the initial exact
        cached = eng.edge_layouts()
        s.query()
        s.query()  # two consecutive queries, no interleaved updates
        assert eng.layout_builds == 1
        assert eng.edge_layouts() is cached  # same tuple, no re-sort
        s.add_edges([0, 1], [2, 3])
        s.query()  # applied update batch -> exactly one re-sort
        assert eng.layout_builds == 2


def test_engine_unresolved_removal_keeps_layout_cache():
    src, dst = gnm_edges(200, 1200, seed=12)
    with repro.session((src, dst), algorithm="pagerank") as s:
        eng = s.engine
        s.query()
        builds = eng.layout_builds
        s.remove_edges([199], [198])  # matches no live edge
        st = s.query().stats
        assert st.removals_requested == 1 and st.removals_resolved == 0
        assert eng.layout_builds == builds


# -------------------------------------------------------- backend selection
def test_resolve_backend_env_override(monkeypatch):
    monkeypatch.setenv(B.BACKEND_ENV_VAR, "pallas")
    assert B.resolve_backend(None) == "pallas"
    assert B.resolve_backend("auto") == "pallas"
    # explicit argument beats the environment
    assert B.resolve_backend("segment_sum") == "segment_sum"
    monkeypatch.setenv(B.BACKEND_ENV_VAR, "auto")
    expected = "pallas" if jax.default_backend() == "tpu" else "segment_sum"
    assert B.resolve_backend(None) == expected
    monkeypatch.setenv(B.BACKEND_ENV_VAR, "bogus")
    with pytest.raises(ValueError):
        B.resolve_backend(None)
    with pytest.raises(ValueError):
        B.resolve_backend("cuda")


def test_engine_config_backend_knob():
    src, dst = gnm_edges(150, 800, seed=13)
    with repro.session((src, dst), algorithm="pagerank",
                       backend="pallas") as sp, \
         repro.session((src, dst), algorithm="pagerank",
                       backend="segment_sum") as ss:
        assert sp.engine.backend == "pallas"
        assert ss.engine.backend == "segment_sum"
        rp = sp.query()
        rs = ss.query()
        np.testing.assert_allclose(rp.scores, rs.scores, **TOL)


def test_build_layout_rejects_reverse_inv_out():
    g = _graph(n=64, m=200, seed=14, n_cap=64)
    with pytest.raises(ValueError):
        B.build_layout(g, weight="inv_out", reverse=True)


def test_mismatched_layout_is_rejected():
    """A cached layout whose baked weights don't match the sweep must fail
    loudly at trace time, not silently mis-weight (e.g. an algorithm
    overriding layout_specs without overriding build_summaries)."""
    g = _graph(n=64, m=400, seed=15, n_cap=64)
    unit = B.build_layout(g, weight="unit")
    rev = B.build_layout(g, weight="unit", reverse=True)
    r0, _ = pagerank(g, num_iters=3)
    hot = _hot(64, seed=2)
    with pytest.raises(ValueError, match="build_summary needs a layout"):
        build_summary(g, r0, hot, hot_node_capacity=64,
                      hot_edge_capacity=512, layout=unit)
    with pytest.raises(ValueError, match="build_summary needs a layout"):
        build_summary(g, r0, hot, hot_node_capacity=64, hot_edge_capacity=512,
                      weight="unit", layout=rev)
    with pytest.raises(ValueError, match="pagerank needs a layout"):
        pagerank(g, num_iters=3, layout=unit)
    from repro.core.hits import hits
    with pytest.raises(ValueError, match="fwd_layout needs a layout"):
        hits(g, num_iters=3, fwd_layout=rev, rev_layout=rev)


def test_push_rejects_chunk_beyond_layout_padding():
    """The kernel's chunk loads are only in-bounds up to the layout's pad."""
    g = _graph(n=64, m=400, seed=16, n_cap=64)
    layout = B.build_layout(g, weight="inv_out", chunk=256)
    r = jnp.ones(64)
    ref = B.push(r, layout, backend="segment_sum")
    out = B.push(r, layout, backend="pallas", chunk=256, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    with pytest.raises(ValueError, match="pad_chunk"):
        B.push(r, layout, backend="pallas", chunk=512, interpret=True)

"""Graph partitioning helpers + ranking utilities."""

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.graph import from_edges
from repro.graph.partition import (edge_sharding, graph_shardings,
                                   host_edge_slice)
from repro.metrics.ranking import l1_delta, linf_delta, top_k_ids


def test_edge_sharding_spec():
    mesh = jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    sh = edge_sharding(mesh, 1024)
    assert sh.spec == P(("data", "model"))


def test_graph_shardings_structure():
    mesh = jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    g = from_edges(np.array([0], np.int32), np.array([1], np.int32), 4, 8)
    sh = graph_shardings(mesh, g)
    assert sh.src.spec == P(("data", "model"))
    assert sh.out_deg.spec == P()


def test_host_edge_slice_covers_all():
    ranges = [host_edge_slice(103, p, 4) for p in range(4)]
    covered = []
    for lo, hi in ranges:
        covered.extend(range(lo, hi))
    assert covered == list(range(103))


def test_top_k_ids_deterministic_ties():
    s = np.array([1.0, 2.0, 2.0, 0.5])
    np.testing.assert_array_equal(top_k_ids(s, 3), [1, 2, 0])


def test_deltas():
    a = np.array([1.0, 2.0, 3.0])
    b = np.array([1.0, 1.0, 5.0])
    assert l1_delta(a, b) == 3.0
    assert linf_delta(a, b) == 2.0
    active = np.array([True, True, False])
    assert l1_delta(a, b, active) == 1.0

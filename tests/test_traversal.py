"""Katz, connected components and SSSP on the streaming engine.

Acceptance contract (ISSUE 3):

- the three workloads are registered algorithms reachable unchanged
  through ``repro.api.session(..., algorithm="sssp", sources=(0,))``;
- exact sweeps match independent numpy references (BFS / union-find /
  dense Katz) on both propagation backends;
- a summarized step over ``hot == all active vertices`` matches the exact
  sweep — *bitwise* for the min-semiring workloads (min has no
  reassociation error), tight-allclose for Katz's float sums;
- streamed replays under the exact policy track the references as the
  graph grows, and approximate replays preserve the workloads' monotone
  invariants.
"""

import collections

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import (Action, ConnectedComponentsAlgorithm, KatzAlgorithm,
                        SSSPAlgorithm, VeilGraphEngine, available_algorithms,
                        make_algorithm)
from repro.core.engine import EngineConfig
from repro.core.policies import always
from repro.core.traversal import LABEL_SENTINEL
from repro.graph import from_edges
from repro.graph.generators import barabasi_albert_edges, gnm_edges


# ------------------------------------------------------- numpy references
def _bfs_dist(n, src, dst, sources):
    adj = collections.defaultdict(list)
    for a, b in zip(src, dst):
        adj[int(a)].append(int(b))
    dist = np.full(n, np.inf, np.float32)
    dq = collections.deque()
    for s in sources:
        dist[s] = 0.0
        dq.append(s)
    while dq:
        u = dq.popleft()
        for v in adj[u]:
            if dist[v] > dist[u] + 1:
                dist[v] = dist[u] + 1
                dq.append(v)
    return dist


def _wcc_labels(n, src, dst):
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    active = np.zeros(n, bool)
    for a, b in zip(src, dst):
        active[a] = active[b] = True
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
    labels = np.full(n, LABEL_SENTINEL, np.int32)
    roots = collections.defaultdict(list)
    for v in range(n):
        if active[v]:
            roots[find(v)].append(v)
    for vs in roots.values():
        labels[vs] = min(vs)
    return labels


def _dense_katz(n, src, dst, alpha, beta, active):
    a_t = np.zeros((n, n))
    for u, v in zip(src, dst):
        a_t[v, u] += 1.0
    c = np.linalg.solve(np.eye(n) - alpha * a_t,
                        beta * np.ones(n)) * active
    return c


def _cfg(n_cap, e_cap, **kw):
    base = dict(node_capacity=n_cap, edge_capacity=e_cap,
                hot_node_capacity=n_cap, hot_edge_capacity=e_cap,
                r=0.2, n=1, delta=0.1)
    base.update(kw)
    return EngineConfig(**base)


# ----------------------------------------------------------- exact sweeps
@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_sssp_exact_matches_bfs(backend):
    from repro.core.traversal import sssp
    src, dst = gnm_edges(300, 1800, seed=0)
    g = from_edges(src, dst, 300, 1864)
    source = jnp.zeros(300, bool).at[jnp.asarray([0, 7])].set(True)
    dist, iters = sssp(g, source, backend=backend)
    ref = _bfs_dist(300, src, dst, [0, 7])
    np.testing.assert_array_equal(np.asarray(dist), ref)
    assert 0 < int(iters) <= 30


@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_cc_exact_matches_union_find(backend):
    from repro.core.traversal import connected_components
    # sparse graph so several components exist
    src, dst = gnm_edges(400, 350, seed=1)
    g = from_edges(src, dst, 400, 414)
    labels, _ = connected_components(g, backend=backend)
    np.testing.assert_array_equal(np.asarray(labels),
                                  _wcc_labels(400, src, dst))
    assert labels.dtype == jnp.int32


def test_katz_exact_matches_dense_solve():
    from repro.core.katz import katz
    src, dst = barabasi_albert_edges(120, 2, seed=2)
    g = from_edges(src, dst, 120, len(src) + 16)
    c, _ = katz(g, alpha=0.02, num_iters=200, tol=1e-10)
    ref = _dense_katz(120, src, dst, 0.02, 1.0,
                      np.asarray(g.node_active))
    np.testing.assert_allclose(np.asarray(c), ref, rtol=2e-5, atol=2e-5)


# -------------------------------------- summarized: hot == all is exact
def test_summarized_sssp_full_hot_set_is_bitwise_exact():
    algo = SSSPAlgorithm(sources=(0, 3), warm_start=True)
    src, dst = gnm_edges(300, 1800, seed=3)
    g = from_edges(src, dst, 300, 1864)
    st0 = algo.init_state(g)
    st, _ = algo.exact(st0, g)
    # grow the graph, then run warm summarized(hot=all) vs warm exact
    from repro.graph.graph import add_edges
    g2 = add_edges(g, jnp.asarray([0, 5, 9], jnp.int32),
                   jnp.asarray([250, 260, 270], jnp.int32))
    hot = jnp.copy(g2.node_active)
    summaries = algo.build_summaries(
        st, g2, hot, hot_node_capacity=300, hot_edge_capacity=2048)
    approx, _ = algo.summarized(st, g2, summaries)
    exact, _ = algo.exact(st, g2)
    np.testing.assert_array_equal(np.asarray(approx["dist"]),
                                  np.asarray(exact["dist"]))
    # min_plus has no reassociation error: equality is bitwise
    assert np.array_equal(np.asarray(approx["delta"]),
                          np.asarray(exact["delta"]))


def test_summarized_cc_full_hot_set_is_bitwise_exact():
    algo = ConnectedComponentsAlgorithm(warm_start=True)
    src, dst = gnm_edges(400, 350, seed=4)
    g = from_edges(src, dst, 400, 414)
    st0 = algo.init_state(g)
    st, _ = algo.exact(st0, g)
    from repro.graph.graph import add_edges
    g2 = add_edges(g, jnp.asarray([0, 17], jnp.int32),
                   jnp.asarray([399, 301], jnp.int32))
    hot = jnp.copy(g2.node_active)
    summaries = algo.build_summaries(
        st, g2, hot, hot_node_capacity=400, hot_edge_capacity=512)
    approx, _ = algo.summarized(st, g2, summaries)
    exact, _ = algo.exact(st, g2)
    np.testing.assert_array_equal(np.asarray(approx["labels"]),
                                  np.asarray(exact["labels"]))


@pytest.mark.parametrize("name,tol", [
    ("katz", 1e-5), ("connected-components", 0.0), ("sssp", 0.0)])
@pytest.mark.parametrize("fused", [True, False])
def test_engine_full_hot_set_matches_exact(name, tol, fused):
    """r < 0 makes every seen vertex hot: the approximate engine action must
    reproduce the exact engine's answer through both query paths."""
    src, dst = barabasi_albert_edges(800, 3, seed=0)
    params = {"katz": dict(alpha=0.01, num_iters=80, tol=1e-9),
              "sssp": dict(sources=(0,))}.get(name, {})
    approx = VeilGraphEngine(
        _cfg(1000, 8192, r=-1.0, delta=1e9, fused=fused),
        make_algorithm(name, **params))
    exact = VeilGraphEngine(
        _cfg(1000, 8192, fused=fused), make_algorithm(name, **params),
        on_query=always(Action.EXACT))
    approx.start(src, dst)
    exact.start(src, dst)
    ra, sa = approx.query()
    re_, se = exact.query()
    assert sa.action == "compute-approximate"
    assert not sa.overflow_fallback
    assert sa.num_hot == sa.num_nodes
    if tol:
        np.testing.assert_allclose(ra, re_, rtol=tol, atol=tol)
    else:
        np.testing.assert_array_equal(ra, re_)


# --------------------------------------------------- session end-to-end
def test_session_sssp_streamed_exact_policy():
    src, dst = barabasi_albert_edges(500, 3, seed=5)
    hold = 120  # stream the tail in later
    s = repro.session((src[:-hold], dst[:-hold]), algorithm="sssp",
                      sources=(0,), node_capacity=600,
                      on_query=always(Action.EXACT))
    r = s.query()
    np.testing.assert_array_equal(
        r.scores, _bfs_dist(600, src[:-hold], dst[:-hold], [0]))
    s.add_edges(src[-hold:], dst[-hold:])
    r2 = s.query()
    np.testing.assert_array_equal(r2.scores, _bfs_dist(600, src, dst, [0]))
    assert r2.stats.algorithm == "sssp"


def test_session_sssp_approximate_keeps_monotone_upper_bound():
    """At paper knobs the approximate distances are always realizable path
    lengths: >= the true distance, and never increasing as edges arrive."""
    src, dst = barabasi_albert_edges(500, 3, seed=6)
    hold = 200
    s = repro.session((src[:-hold], dst[:-hold]), algorithm="sssp",
                      sources=(0,), node_capacity=600, r=0.2, delta=0.1)
    prev = s.query().scores
    for lo in range(len(src) - hold, len(src), 50):
        s.add_edges(src[lo:lo + 50], dst[lo:lo + 50])
        cur = s.query().scores
        assert (cur <= prev + 1e-6).all()  # monotone under additions
        prev = cur
    true = _bfs_dist(600, src, dst, [0])
    assert (prev >= true - 1e-6).all()     # never better than possible
    # and the hot-set machinery actually restricted the work
    st = s.stats_log[-1]
    assert 0 < st.num_hot < st.num_nodes


def test_session_cc_streamed_exact_policy():
    rng = np.random.default_rng(7)
    src = rng.integers(0, 400, 300).astype(np.int32)
    dst = rng.integers(0, 400, 300).astype(np.int32)
    s = repro.session((src, dst), algorithm="connected-components",
                      node_capacity=450, on_query=always(Action.EXACT))
    np.testing.assert_array_equal(s.query().scores[:400],
                                  _wcc_labels(400, src, dst)[:400])
    # merge two components and stream a brand-new vertex in
    s.add_edges([0, 420], [399, 0])
    out = s.query().scores
    ref = _wcc_labels(450, np.concatenate([src, [0, 420]]),
                      np.concatenate([dst, [399, 0]]))
    np.testing.assert_array_equal(out, ref)
    assert out.dtype == np.int32


def test_session_katz_streamed():
    src, dst = barabasi_albert_edges(200, 2, seed=8)
    s = repro.session((src, dst), algorithm="katz", alpha=0.02,
                      num_iters=200, tol=1e-10,
                      on_query=always(Action.EXACT))
    r = s.query()
    ref = _dense_katz(s.engine.config.node_capacity, src, dst, 0.02, 1.0,
                      np.asarray(s.engine.state.node_active))
    np.testing.assert_allclose(r.scores, ref, rtol=2e-5, atol=2e-5)


# ------------------------------------------------- registry and contract
def test_top_masks_padding_and_orders_by_algorithm_direction():
    """session top() must never surface capacity-padding / sentinel
    vertices, and must rank ascending for distance/label workloads."""
    src = np.asarray([0, 1, 2, 3], np.int32)
    dst = np.asarray([1, 2, 3, 0], np.int32)
    s = repro.session((src, dst), algorithm="connected-components",
                      node_capacity=20)
    r = s.query()
    top = r.top(10)
    assert set(top) <= {0, 1, 2, 3}  # no phantom padding ids
    assert len(top) == 4
    np.testing.assert_array_equal(np.sort(r.scores[top]), r.scores[top])
    # sssp: nearest-first, unreachable/inactive excluded
    s2 = repro.session((src, dst), algorithm="sssp", sources=(0,),
                       node_capacity=20)
    r2 = s2.query()
    top2 = r2.top(10)
    assert list(top2)[0] == 0 and set(top2) <= {0, 1, 2, 3}
    assert (np.diff(r2.scores[top2]) >= 0).all()
    assert np.array_equal(s2.top(10), top2)  # session.top agrees
    # ranking algorithms keep descending order
    r3 = repro.session((src, dst), algorithm="pagerank",
                       node_capacity=20).query()
    assert (np.diff(r3.scores[r3.top(4)]) <= 0).all()


def test_cc_single_cached_layout_per_direction():
    """A caller with only one of the two directional layouts cached must
    not crash (and must still be correct) on either backend."""
    from repro.core.backend import build_layout
    from repro.core.traversal import connected_components
    src, dst = gnm_edges(200, 180, seed=14)
    g = from_edges(src, dst, 200, 200)
    ref = _wcc_labels(200, src, dst)
    fwd = build_layout(g, weight="unit", semiring="min_min")
    rev = build_layout(g, weight="unit", semiring="min_min", reverse=True)
    for backend in ("segment_sum", "pallas"):
        for kw in (dict(fwd_layout=fwd), dict(rev_layout=rev),
                   dict(fwd_layout=fwd, rev_layout=rev)):
            labels, _ = connected_components(g, backend=backend, **kw)
            np.testing.assert_array_equal(np.asarray(labels), ref)


def test_new_algorithms_registered():
    listed = set(available_algorithms())
    assert {"katz", "connected-components", "sssp"} <= listed
    assert isinstance(make_algorithm("cc"), ConnectedComponentsAlgorithm)
    assert isinstance(make_algorithm("wcc"), ConnectedComponentsAlgorithm)
    assert isinstance(make_algorithm("shortest-paths", sources=(3,)),
                      SSSPAlgorithm)
    a = make_algorithm("sssp", sources=(1, 2))
    assert a.sources == (1, 2)
    with pytest.raises(ValueError):
        SSSPAlgorithm(sources=())
    with pytest.raises(ValueError):
        KatzAlgorithm(alpha=1.5)


def test_sssp_source_validation_through_session():
    src = np.asarray([0, 1, 2], np.int32)
    dst = np.asarray([1, 2, 0], np.int32)
    with pytest.raises(ValueError, match="node_capacity"):
        repro.session((src, dst), algorithm="sssp", sources=(10_000,))
    with pytest.raises(ValueError, match="negative"):
        repro.session((src, dst), algorithm="sssp", sources=(-1,))


def test_state_dtype_declarations_validated():
    """state_dtypes is enforced at engine init: an int workload whose
    plugin accidentally produces floats must fail loudly."""

    class BrokenCC(ConnectedComponentsAlgorithm):
        def init_state(self, graph):
            st = super().init_state(graph)
            return {**st, "labels": st["labels"].astype(jnp.float32)}

    with pytest.raises(ValueError, match="declared int32"):
        VeilGraphEngine(_cfg(16, 64), BrokenCC())
    # declared keys must exist at all
    class MissingKey(ConnectedComponentsAlgorithm):
        def init_state(self, graph):
            st = super().init_state(graph)
            return {"labels": st["labels"]}

    with pytest.raises(ValueError, match="missing declared"):
        VeilGraphEngine(_cfg(16, 64), MissingKey())


def test_selection_view_is_churn_for_traversal_workloads():
    """CC/SSSP drive the Δ policy with churn indicators, not raw state —
    and the legacy score_view alias still reports the result view."""
    src, dst = gnm_edges(100, 400, seed=9)
    eng = VeilGraphEngine(_cfg(120, 512), "sssp")
    eng.start(src, dst)
    sel = np.asarray(eng.algorithm.selection_view(eng.algo_state))
    assert sel.dtype == np.float32
    assert np.isfinite(sel).all()  # churn indicators, never ±inf
    res = np.asarray(eng.algorithm.result_view(eng.algo_state))
    legacy = np.asarray(eng.algorithm.score_view(eng.algo_state))
    np.testing.assert_array_equal(res, legacy)
    assert np.isinf(res).any() or (res >= 0).all()  # distances, not churn


def test_legacy_score_view_only_subclass_still_works():
    """Pre-semiring plugins that override score_view (not result_view)
    keep steering the engine — including subclasses of shipped
    algorithms, whose inherited result_view must not shadow the
    customization."""
    from dataclasses import dataclass
    from repro.core import PageRankAlgorithm

    @dataclass(frozen=True)
    class OldStyle(PageRankAlgorithm):
        name = "old-style"

        def score_view(self, state):  # the pre-split override point
            return state["ranks"] * 2.0

    src, dst = gnm_edges(50, 200, seed=10)
    eng = VeilGraphEngine(_cfg(60, 256), OldStyle())
    eng.start(src, dst)
    scores, st = eng.query()
    assert st.action == "compute-approximate"
    # the engine's answer is the score_view override, not raw ranks
    np.testing.assert_allclose(
        scores, 2.0 * np.asarray(eng.algo_state["ranks"]), rtol=1e-6)
    # a legacy override chaining up via super().score_view must get its
    # parent's answer, not itself back (no mutual recursion)
    @dataclass(frozen=True)
    class Chained(PageRankAlgorithm):
        name = "chained"

        def score_view(self, state):
            return super().score_view(state) * 3.0

    eng_c = VeilGraphEngine(_cfg(60, 256), Chained())
    eng_c.start(src, dst)
    np.testing.assert_allclose(
        np.asarray(eng_c.ranks), 3.0 * np.asarray(eng_c.algo_state["ranks"]),
        rtol=1e-6)
    # score_view supplied by a mixin (precedes the base in the MRO without
    # subclassing it) must also win
    class ScoreMixin:
        def score_view(self, state):
            return state["ranks"] * 5.0

    @dataclass(frozen=True)
    class Mixed(ScoreMixin, PageRankAlgorithm):
        name = "mixed"

    eng_m = VeilGraphEngine(_cfg(60, 256), Mixed())
    eng_m.start(src, dst)
    np.testing.assert_allclose(
        np.asarray(eng_m.ranks), 5.0 * np.asarray(eng_m.algo_state["ranks"]),
        rtol=1e-6)
    # ...and a modern subclass that defines result_view is left alone
    @dataclass(frozen=True)
    class NewStyle(PageRankAlgorithm):
        name = "new-style"

        def result_view(self, state):
            return state["ranks"] + 1.0

    eng2 = VeilGraphEngine(_cfg(60, 256), NewStyle())
    eng2.start(src, dst)
    np.testing.assert_allclose(
        np.asarray(eng2.ranks), np.asarray(eng2.algo_state["ranks"]) + 1.0,
        rtol=1e-6)


def test_plugin_with_no_view_method_fails_at_construction():
    """result_view stays abstract: a plugin implementing neither view
    method must fail at instantiation, not at first query."""
    from dataclasses import dataclass
    from repro.core import StreamingAlgorithm

    @dataclass(frozen=True)
    class NoView(StreamingAlgorithm):
        name = "no-view"

        def init_state(self, graph):
            return {}

        def exact(self, state, graph, *, layouts=None, backend=None):
            return state, jnp.int32(0)

        def summarized(self, state, graph, summaries, *, backend=None):
            return state, jnp.int32(0)

    with pytest.raises(TypeError, match="abstract"):
        NoView()


def test_legacy_plugin_with_custom_state_keys_constructs():
    """An old plugin whose state has no 'ranks' key (and declares no
    state_dtypes) must not trip the new dtype validation."""
    from dataclasses import dataclass
    from repro.core import PageRankAlgorithm

    @dataclass(frozen=True)
    class Renamed(PageRankAlgorithm):
        name = "renamed-state"
        state_dtypes = {}

        def init_state(self, graph):
            return {"scores": super().init_state(graph)["ranks"]}

        def exact(self, state, graph, *, layouts=None, backend=None):
            st, it = super().exact({"ranks": state["scores"]}, graph,
                                   layouts=layouts, backend=backend)
            return {"scores": st["ranks"]}, it

        def summarized(self, state, graph, summaries, *, backend=None):
            st, it = super().summarized({"ranks": state["scores"]}, graph,
                                        summaries, backend=backend)
            return {"scores": st["ranks"]}, it

        def score_view(self, state):
            return state["scores"]

    src, dst = gnm_edges(50, 200, seed=11)
    eng = VeilGraphEngine(_cfg(60, 256), Renamed())
    eng.start(src, dst)
    scores, st = eng.query()
    assert np.isfinite(scores).all()


def test_summarized_sssp_honors_explicit_edge_lengths():
    """build_summary(weight='length', lengths=...) must bake the real
    lengths into E_K, not hop counts (b_in already used them)."""
    from repro.core.backend import build_layout
    from repro.core.pagerank import build_summary
    from repro.core.traversal import sssp, summarized_sssp

    src, dst = gnm_edges(120, 600, seed=12)
    g = from_edges(src, dst, 120, 664)
    rng = np.random.default_rng(13)
    lengths = jnp.asarray(
        (1.0 + 9.0 * rng.random(g.edge_capacity)).astype(np.float32))
    layout = build_layout(g, weight="length", semiring="min_plus",
                          lengths=lengths)
    source = jnp.zeros(120, bool).at[0].set(True)
    dist, _ = sssp(g, source, layout=layout)
    hot = jnp.copy(g.node_active)
    # the layout's baked lengths are authoritative: no lengths= needed
    summary = build_summary(g, dist, hot, hot_node_capacity=120,
                            hot_edge_capacity=1024, weight="length",
                            semiring="min_plus", layout=layout)
    again, _ = summarized_sssp(summary, dist, source)
    # the converged weighted distances are a fixed point of the summarized
    # relaxation only if E_K carries the same lengths
    np.testing.assert_array_equal(np.asarray(again), np.asarray(dist))
    # and a partial hot set relaxes *with* lengths from a degraded start
    hot2 = jnp.asarray(rng.random(120) < 0.6) & g.node_active
    summary2 = build_summary(g, dist, hot2, hot_node_capacity=120,
                             hot_edge_capacity=1024, weight="length",
                             semiring="min_plus", lengths=lengths)
    relaxed, _ = summarized_sssp(summary2, dist, source)
    np.testing.assert_array_equal(np.asarray(relaxed), np.asarray(dist))

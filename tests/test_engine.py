"""End-to-end engine behaviour: Alg. 1 workflow, policies, accuracy."""

import numpy as np
import pytest

from repro.core import Action, EngineConfig, VeilGraphEngine
from repro.core.policies import (always, exact_above_entropy, periodic_exact,
                                 repeat_below_threshold)
from repro.graph.generators import barabasi_albert_edges
from repro.metrics import rbo_from_scores
from repro.stream import StreamConfig, build_stream


def _cfg(fused=True, **kw):
    base = dict(node_capacity=1200, edge_capacity=8192,
                hot_node_capacity=1024, hot_edge_capacity=8192,
                r=0.2, n=1, delta=0.1, num_iters=30, tol=1e-6, fused=fused)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def stream():
    src, dst = barabasi_albert_edges(1000, 3, seed=0)
    sc = StreamConfig(stream_size=600, num_queries=6, shuffle=True, seed=2)
    return build_stream(src, dst, sc)


@pytest.mark.parametrize("fused", [True, False])
def test_engine_accuracy_vs_exact(stream, fused):
    eng = VeilGraphEngine(_cfg(fused=fused))
    eng.start(stream.init_src, stream.init_dst)
    ex = VeilGraphEngine(_cfg(fused=fused), on_query=always(Action.EXACT))
    ex.start(stream.init_src, stream.init_dst)
    for s, d in stream:
        eng.register_add_edges(s, d)
        ex.register_add_edges(s, d)
        ra, sa = eng.query()
        re_, se = ex.query()
        rbo = rbo_from_scores(ra, re_, depth=200,
                              active=np.asarray(eng.state.node_active))
        assert rbo > 0.9
        assert sa.action in ("compute-approximate",)
        assert se.action == "compute-exact"
    # stats sanity
    assert sa.num_hot >= sa.num_kr
    assert 0.0 <= sa.vertex_ratio <= 1.0
    assert 0.0 <= sa.edge_ratio <= 1.0


def test_fused_and_unfused_agree(stream):
    res = {}
    for fused in (True, False):
        eng = VeilGraphEngine(_cfg(fused=fused))
        eng.start(stream.init_src, stream.init_dst)
        for s, d in stream:
            eng.register_add_edges(s, d)
            ranks, st = eng.query()
        res[fused] = (ranks, st)
    # fused/unfused differ only by f32 summation order; vertices exactly at
    # the Δ-expansion boundary may flip, so require agreement up to rounding.
    np.testing.assert_allclose(res[True][0], res[False][0], rtol=1e-3, atol=1e-4)
    assert abs(res[True][1].num_hot - res[False][1].num_hot) <= max(5, res[False][1].num_hot // 100)
    assert abs(res[True][1].num_ek - res[False][1].num_ek) <= max(20, res[False][1].num_ek // 50)


def test_repeat_last_policy(stream):
    eng = VeilGraphEngine(_cfg(), on_query=repeat_below_threshold(10**9))
    eng.start(stream.init_src, stream.init_dst)
    r0 = np.asarray(eng.ranks)
    s, d = stream.chunks[0]
    eng.register_add_edges(s, d)
    ranks, st = eng.query()
    assert st.action == "repeat-last-answer"
    np.testing.assert_array_equal(ranks, r0)


def test_entropy_policy_switches_to_exact(stream):
    eng = VeilGraphEngine(_cfg(), on_query=exact_above_entropy(1e-9))
    eng.start(stream.init_src, stream.init_dst)
    s, d = stream.chunks[0]
    eng.register_add_edges(s, d)
    _, st = eng.query()
    assert st.action == "compute-exact"


def test_periodic_exact_policy(stream):
    eng = VeilGraphEngine(_cfg(), on_query=periodic_exact(2))
    eng.start(stream.init_src, stream.init_dst)
    actions = []
    for s, d in stream:
        eng.register_add_edges(s, d)
        _, st = eng.query()
        actions.append(st.action)
    assert actions[0] == "compute-approximate"
    assert actions[2] == "compute-exact"
    assert actions[4] == "compute-exact"


def test_overflow_falls_back_to_exact(stream):
    cfg = _cfg(hot_node_capacity=2, hot_edge_capacity=4, r=0.0, delta=1e-6)
    eng = VeilGraphEngine(cfg)
    eng.start(stream.init_src, stream.init_dst)
    ex = VeilGraphEngine(_cfg(), on_query=always(Action.EXACT))
    ex.start(stream.init_src, stream.init_dst)
    s, d = stream.chunks[0]
    eng.register_add_edges(s, d)
    ex.register_add_edges(s, d)
    ra, st = eng.query()
    re_, _ = ex.query()
    assert st.overflow_fallback
    # fallback result must equal the exact recomputation
    np.testing.assert_allclose(ra, re_, rtol=1e-5, atol=1e-6)


def test_udf_callbacks_fire(stream):
    calls = []
    eng = VeilGraphEngine(
        _cfg(),
        on_start=lambda e: calls.append("start"),
        on_query_result=lambda qid, msg, action, ranks, st: calls.append(("result", qid)),
        on_stop=lambda e: calls.append("stop"),
    )
    eng.start(stream.init_src, stream.init_dst)
    s, d = stream.chunks[0]
    eng.register_add_edges(s, d)
    eng.query()
    eng.stop()
    assert calls == ["start", ("result", 0), "stop"]


def test_before_updates_can_defer(stream):
    eng = VeilGraphEngine(_cfg(), before_updates=lambda pending, view: False)
    eng.start(stream.init_src, stream.init_dst)
    e0 = int(eng.state.num_live_edges())
    s, d = stream.chunks[0]
    eng.register_add_edges(s, d)
    _, st = eng.query()
    assert int(eng.state.num_live_edges()) == e0  # updates deferred
    assert eng.pending_updates == len(s)
    assert st.pending_applied == 0


def test_edge_removal_stream(stream):
    """Beyond-paper (the paper's §7 future work): e- removals through the
    engine; removed edges stop contributing and the approximate result
    tracks an exact engine fed the same removal stream."""
    eng = VeilGraphEngine(_cfg())
    eng.start(stream.init_src, stream.init_dst)
    ex = VeilGraphEngine(_cfg(), on_query=always(Action.EXACT))
    ex.start(stream.init_src, stream.init_dst)
    # remove a slice of initial edges + add a chunk
    rm_s, rm_d = stream.init_src[:40], stream.init_dst[:40]
    add_s, add_d = stream.chunks[0]
    for e in (eng, ex):
        e.register_remove_edges(rm_s, rm_d)
        e.register_add_edges(add_s, add_d)
    ra, sa = eng.query()
    re_, se = ex.query()
    assert int(eng.state.num_live_edges()) == int(ex.state.num_live_edges())
    rbo = rbo_from_scores(ra, re_, depth=200,
                          active=np.asarray(eng.state.node_active))
    assert rbo > 0.9
    assert sa.pending_applied == 40 + len(add_s)
